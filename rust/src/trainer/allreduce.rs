//! Ring all-reduce over the message transport — the synchronous-SGD
//! parameter synchronization (the paper delegates this to PyTorch DDP;
//! here it is a first-class component so its network cost is metered like
//! everything else).
//!
//! Standard two-phase ring: reduce-scatter (N-1 steps) then all-gather
//! (N-1 steps); each trainer sends `2 * (N-1)/N * bytes` per reduction.
//! Cross-machine hops are charged to the cost model by the transport's
//! endpoint→machine mapping; same-machine hops are free (NVLink/shared
//! memory in the paper's g4dn nodes).

use std::fmt;
use std::sync::Arc;
use std::time::Duration;

use crate::net::transport::{Endpoint, Port, PortKind, Transport};
use crate::net::CostModel;

/// Typed collective failures. A duplicate-rank bug or a dropped ring
/// peer surfaces as a descriptive `Err` the caller can drain on — not
/// a panic that poisons the group mutex across trainer threads.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllReduceError {
    /// `endpoint(rank)` was called twice for the same rank.
    AlreadyClaimed { rank: usize },
    /// `endpoint(rank)` with `rank >= world`.
    RankOutOfRange { rank: usize, world: usize },
    /// `endpoint(rank)` for a rank this process does not host (TCP
    /// backend: each process claims only its own ring participants).
    RankNotLocal { rank: usize },
    /// A ring neighbour dropped mid-collective: its mailbox closed, a
    /// send failed at the transport, or no step frame arrived within
    /// `recv_timeout` — the reduction cannot complete. With the
    /// in-process transport the fabric outlives every participant;
    /// over TCP this is how a peer-process crash surfaces. Live-rank
    /// loss is handled above the ring (the coordinator keeps dead
    /// ranks participating as zombies until the epoch boundary).
    PeerDropped { rank: usize, phase: &'static str, step: usize },
}

impl fmt::Display for AllReduceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::AlreadyClaimed { rank } => write!(
                f,
                "all-reduce participant {rank} already claimed \
                 (duplicate rank in the trainer grid?)"
            ),
            Self::RankOutOfRange { rank, world } => write!(
                f,
                "all-reduce rank {rank} out of range for world {world}"
            ),
            Self::RankNotLocal { rank } => write!(
                f,
                "all-reduce rank {rank} is hosted by another process"
            ),
            Self::PeerDropped { rank, phase, step } => write!(
                f,
                "ring peer of rank {rank} dropped during {phase} \
                 step {step}"
            ),
        }
    }
}

impl std::error::Error for AllReduceError {}

pub struct AllReduceGroup {
    /// Keeps the fabric (and its cost meter) alive for the group's life.
    pub transport: Arc<Transport>,
    n: usize,
    /// `local[t]` — this process hosts rank t's endpoint (always true
    /// with the in-process backend).
    local: Vec<bool>,
    endpoints: std::sync::Mutex<Vec<Option<Endpoint>>>,
}

impl AllReduceGroup {
    /// `machine_of[t]` = machine of trainer t.
    pub fn new(machine_of: Vec<u32>, cost: Arc<CostModel>) -> Arc<Self> {
        let n = machine_of.len();
        let transport = Transport::with_mapping(machine_of, cost);
        Self::from_transport(transport, n)
    }

    /// Build the ring over an existing transport whose endpoints
    /// `0..world` are the trainer ranks (any endpoints past `world`
    /// belong to other services and are left alone). Only ranks hosted
    /// by *this* process are claimed — over TCP, each process builds
    /// its own group from its own transport and the ring spans the
    /// processes through the shared endpoint space.
    pub fn from_transport(
        transport: Arc<Transport>,
        world: usize,
    ) -> Arc<Self> {
        assert!(
            world <= transport.n_endpoints(),
            "ring world {world} exceeds {} transport endpoints",
            transport.n_endpoints()
        );
        let local: Vec<bool> = (0..world as u32)
            .map(|t| transport.hosts_endpoint(t))
            .collect();
        let endpoints = (0..world as u32)
            .map(|t| {
                local[t as usize].then(|| transport.endpoint(t))
            })
            .collect();
        Arc::new(Self {
            transport,
            n: world,
            local,
            endpoints: std::sync::Mutex::new(endpoints),
        })
    }

    /// Claim trainer `t`'s participant handle (once). A second claim,
    /// an out-of-range rank, or a rank another process hosts is a
    /// typed error, and the group stays usable for the other ranks.
    pub fn endpoint(
        self: &Arc<Self>,
        t: usize,
    ) -> Result<Participant, AllReduceError> {
        let mut slots = self.endpoints.lock().unwrap();
        if t >= slots.len() {
            return Err(AllReduceError::RankOutOfRange {
                rank: t,
                world: self.n,
            });
        }
        if !self.local[t] {
            return Err(AllReduceError::RankNotLocal { rank: t });
        }
        let ep = slots[t]
            .take()
            .ok_or(AllReduceError::AlreadyClaimed { rank: t })?;
        Ok(Participant {
            ep,
            rank: t,
            n: self.n,
            seq: std::cell::Cell::new(0),
            recv_timeout: Duration::from_secs(30),
        })
    }
}

pub struct Participant {
    ep: Endpoint,
    pub rank: usize,
    pub n: usize,
    seq: std::cell::Cell<u64>,
    /// How long one ring step may wait for the left neighbour's frame
    /// before the peer is declared dropped.
    pub recv_timeout: Duration,
}

impl fmt::Debug for Participant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Participant(rank {}/{})", self.rank, self.n)
    }
}

impl Participant {
    /// One ring-step receive: only Trainer-port frames, bounded wait.
    fn recv_step(
        &self,
        phase: &'static str,
        step: usize,
    ) -> Result<crate::net::Message, AllReduceError> {
        self.ep
            .recv_kind(PortKind::Trainer, Some(self.recv_timeout))
            .ok_or(AllReduceError::PeerDropped {
                rank: self.rank,
                phase,
                step,
            })
    }

    /// In-place mean all-reduce across the group. All participants must
    /// call with identically-shaped data each round.
    pub fn allreduce_mean(
        &self,
        data: &mut [f32],
    ) -> Result<(), AllReduceError> {
        if self.n == 1 {
            return Ok(());
        }
        let seq = self.seq.get();
        self.seq.set(seq + 1);
        let n = self.n;
        let rank = self.rank;
        let next = ((rank + 1) % n) as u32;

        // chunk boundaries (n chunks, last absorbs remainder)
        let data_len = data.len();
        let chunk = move |i: usize| -> std::ops::Range<usize> {
            let base = data_len / n;
            let lo = i * base;
            let hi = if i + 1 == n { data_len } else { lo + base };
            lo..hi
        };

        // phase 1: reduce-scatter. step s: send chunk (rank - s), add into
        // chunk (rank - s - 1) received from the left.
        for s in 0..n - 1 {
            let send_idx = (rank + n - s) % n;
            let r = chunk(send_idx);
            self.ep
                .send(
                    next,
                    Port::Trainer(self.rank as u32),
                    tag(seq, 0, s),
                    f32s_to_bytes(&data[r]),
                )
                .map_err(|_| AllReduceError::PeerDropped {
                    rank,
                    phase: "reduce-scatter",
                    step: s,
                })?;
            let msg = self.recv_step("reduce-scatter", s)?;
            debug_assert_eq!(msg.tag, tag(seq, 0, s));
            let recv_idx = (rank + n - s - 1) % n;
            let r = chunk(recv_idx);
            // §Perf: accumulate straight from the wire bytes (no temp vec)
            for (d, c) in
                data[r].iter_mut().zip(msg.payload.chunks_exact(4))
            {
                *d += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        // phase 2: all-gather. step s: send chunk (rank + 1 - s), replace
        // chunk (rank - s) with the received fully-reduced chunk.
        for s in 0..n - 1 {
            let send_idx = (rank + 1 + n - s) % n;
            let r = chunk(send_idx);
            self.ep
                .send(
                    next,
                    Port::Trainer(self.rank as u32),
                    tag(seq, 1, s),
                    f32s_to_bytes(&data[r]),
                )
                .map_err(|_| AllReduceError::PeerDropped {
                    rank,
                    phase: "all-gather",
                    step: s,
                })?;
            let msg = self.recv_step("all-gather", s)?;
            debug_assert_eq!(msg.tag, tag(seq, 1, s));
            let recv_idx = (rank + n - s) % n;
            let r = chunk(recv_idx);
            for (d, c) in
                data[r].iter_mut().zip(msg.payload.chunks_exact(4))
            {
                *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        let inv = 1.0 / n as f32;
        for d in data.iter_mut() {
            *d *= inv;
        }
        Ok(())
    }

    /// Restart support (docs/DESIGN.md §12): resume the ring-frame tag
    /// sequence at `seq` — the number of all-reduce rounds this rank
    /// completed before its process died — so a rejoined participant's
    /// tags line up with the rounds its peers are already on.
    pub fn set_seq(&self, seq: u64) {
        self.seq.set(seq);
    }

    /// Mean all-reduce over a parameter list (flattens per tensor).
    pub fn allreduce_params(
        &self,
        params: &mut [Vec<f32>],
    ) -> Result<(), AllReduceError> {
        // single flat buffer: fewer ring rounds, matches DDP bucketing
        let total: usize = params.iter().map(|p| p.len()).sum();
        let mut flat = Vec::with_capacity(total);
        for p in params.iter() {
            flat.extend_from_slice(p);
        }
        self.allreduce_mean(&mut flat)?;
        let mut off = 0;
        for p in params.iter_mut() {
            let len = p.len();
            p.copy_from_slice(&flat[off..off + len]);
            off += len;
        }
        Ok(())
    }
}

fn tag(seq: u64, phase: u64, step: usize) -> u64 {
    (seq << 16) | (phase << 8) | step as u64
}

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn run_group(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let cost = Arc::new(CostModel::default());
        let group = AllReduceGroup::new((0..n as u32).collect(), cost);
        let mut rng = Rng::new(seed);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut handles = Vec::new();
        for (t, mut data) in inputs.clone().into_iter().enumerate() {
            let p = group.endpoint(t).unwrap();
            handles.push(std::thread::spawn(move || {
                p.allreduce_mean(&mut data).unwrap();
                data
            }));
        }
        let outputs: Vec<Vec<f32>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // expected serial mean
        let mut expect = vec![0f32; len];
        for inp in &inputs {
            for (e, x) in expect.iter_mut().zip(inp) {
                *e += x;
            }
        }
        for e in expect.iter_mut() {
            *e /= n as f32;
        }
        let mut all = outputs;
        all.push(expect);
        all
    }

    #[test]
    fn equals_serial_mean_various_sizes() {
        for (n, len) in [(2, 10), (3, 7), (4, 64), (5, 3), (2, 1)] {
            let mut all = run_group(n, len, n as u64 * 31 + len as u64);
            let expect = all.pop().unwrap();
            for (t, out) in all.iter().enumerate() {
                for (a, b) in out.iter().zip(&expect) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "n={n} len={len} trainer {t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn all_ranks_agree() {
        let mut all = run_group(4, 100, 9);
        all.pop();
        for w in all.windows(2) {
            assert_eq!(w[0], w[1]);
        }
    }

    #[test]
    fn repeated_rounds_with_param_lists() {
        let n = 3;
        let cost = Arc::new(CostModel::default());
        let group = AllReduceGroup::new((0..n as u32).collect(), cost);
        let mut handles = Vec::new();
        for t in 0..n {
            let p = group.endpoint(t as usize).unwrap();
            handles.push(std::thread::spawn(move || {
                let mut params =
                    vec![vec![t as f32; 5], vec![(t * 10) as f32; 3]];
                for _round in 0..4 {
                    p.allreduce_params(&mut params).unwrap();
                }
                params
            }));
        }
        let outs: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // mean of 0,1,2 = 1.0; mean of 0,10,20 = 10.0 (idempotent rounds)
        for o in &outs {
            assert!(o[0].iter().all(|&x| (x - 1.0).abs() < 1e-5));
            assert!(o[1].iter().all(|&x| (x - 10.0).abs() < 1e-5));
        }
    }

    #[test]
    fn cross_machine_traffic_is_metered() {
        let cost = Arc::new(CostModel::default());
        // 4 trainers on 2 machines: ring 0->1->2->3->0 has 2 cross links
        let group =
            AllReduceGroup::new(vec![0, 0, 1, 1], cost.clone());
        let mut handles = Vec::new();
        for t in 0..4 {
            let p = group.endpoint(t).unwrap();
            handles.push(std::thread::spawn(move || {
                let mut d = vec![t as f32; 40];
                p.allreduce_mean(&mut d).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let bytes = cost.network_bytes();
        assert!(bytes > 0);
        // only 2 of 4 hops cross machines: strictly less than total
        // volume — n * phases * steps * (chunk + frame header)
        let total_payload =
            4 * 2 * 3 * (10 * 4 + crate::net::wire::FRAME_HEADER_BYTES);
        assert!(bytes < total_payload as u64, "{bytes}");
    }

    #[test]
    fn duplicate_claim_is_a_typed_error_not_a_panic() {
        let cost = Arc::new(CostModel::default());
        let group = AllReduceGroup::new(vec![0, 0], cost);
        let _p0 = group.endpoint(0).unwrap();
        assert_eq!(
            group.endpoint(0).unwrap_err(),
            AllReduceError::AlreadyClaimed { rank: 0 }
        );
        // the group mutex is not poisoned: other ranks still claim
        let _p1 = group.endpoint(1).unwrap();
        let msg =
            AllReduceError::AlreadyClaimed { rank: 0 }.to_string();
        assert!(msg.contains("participant 0"), "{msg}");
    }

    #[test]
    fn out_of_range_rank_is_a_typed_error() {
        let cost = Arc::new(CostModel::default());
        let group = AllReduceGroup::new(vec![0, 1], cost);
        assert_eq!(
            group.endpoint(7).unwrap_err(),
            AllReduceError::RankOutOfRange { rank: 7, world: 2 }
        );
    }

    #[test]
    fn tcp_ring_matches_in_process_ring() {
        use crate::net::tcp::{
            free_loopback_ports, tcp_transport, TcpConfig,
        };
        // reference: the in-process ring
        let mut expect = run_group(2, 12, 77);
        let expect = expect.pop().unwrap();

        let ports = free_loopback_ports(2).unwrap();
        let addrs: Vec<String> =
            ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();
        let mk = |my_proc: usize| {
            let mut cfg = TcpConfig::localhost(my_proc, 2, 0);
            cfg.addrs = addrs.clone();
            tcp_transport(cfg, Arc::new(CostModel::default())).unwrap()
        };
        let inputs: Vec<Vec<f32>> = {
            let mut rng = Rng::new(77);
            (0..2)
                .map(|_| {
                    (0..12).map(|_| rng.normal() as f32).collect()
                })
                .collect()
        };
        let mut handles = Vec::new();
        for (t, mut data) in inputs.into_iter().enumerate() {
            let transport = mk(t);
            handles.push(std::thread::spawn(move || {
                // each "process" claims exactly its own rank
                let group =
                    AllReduceGroup::from_transport(transport, 2);
                assert_eq!(
                    group.endpoint(1 - t).unwrap_err(),
                    AllReduceError::RankNotLocal { rank: 1 - t }
                );
                let p = group.endpoint(t).unwrap();
                p.allreduce_mean(&mut data).unwrap();
                data
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            for (a, b) in out.iter().zip(&expect) {
                assert!(
                    (a - b).abs() < 1e-5,
                    "TCP ring ≡ in-process ring: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn dead_ring_peer_is_peer_dropped_not_a_hang() {
        let cost = Arc::new(CostModel::default());
        let group = AllReduceGroup::new(vec![0, 1], cost);
        let mut p = group.endpoint(0).unwrap();
        // rank 1 never participates: the step times out into a typed
        // error instead of blocking forever
        p.recv_timeout = Duration::from_millis(40);
        let mut d = vec![1.0f32; 8];
        assert_eq!(
            p.allreduce_mean(&mut d).unwrap_err(),
            AllReduceError::PeerDropped {
                rank: 0,
                phase: "reduce-scatter",
                step: 0
            }
        );
    }
}
