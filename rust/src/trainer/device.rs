//! Device executor: owns the PJRT client + compiled executables for one
//! machine's accelerator and serializes step requests from that machine's
//! trainers.
//!
//! PJRT handles are not `Send`, so the executor thread constructs the
//! `RuntimeEnv` itself and trainers talk to it through a channel. On this
//! one-core testbed all device compute serializes anyway; per-GPU *scaling*
//! is reported through the device cost model (DESIGN.md §2).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::net::CostModel;
use crate::runtime::executable::HostBatch;
use crate::runtime::manifest::VariantSpec;

enum Req {
    Train {
        params: Vec<Vec<f32>>,
        batch: Box<HostBatch>,
        lr: f32,
        /// Replies with (updated params, loss, the spent batch back —
        /// so the caller can recycle its buffers through a `BatchPool`).
        reply: Sender<Result<(Vec<Vec<f32>>, f32, Box<HostBatch>)>>,
    },
    Eval {
        params: Vec<Vec<f32>>,
        batch: Box<HostBatch>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Spec {
        reply: Sender<Result<VariantSpec>>,
    },
    InitialParams {
        reply: Sender<Result<Vec<Vec<f32>>>>,
    },
    Stop,
}

/// Owner handle (also usable as a request handle via [`Self::handle`]).
pub struct DeviceExecutor {
    tx: Sender<Req>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Cloneable request handle for trainer threads.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Sender<Req>,
}

impl DeviceExecutor {
    /// Spawn the executor thread; compiles `variant` from `artifacts`.
    pub fn spawn(
        artifacts: PathBuf,
        variant: String,
        pcie: Option<Arc<CostModel>>,
    ) -> Result<DeviceExecutor> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("device-{variant}"))
            .spawn(move || run_executor(artifacts, variant, pcie, rx, ready_tx))
            .expect("spawn device executor");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("device executor died during init"))??;
        Ok(DeviceExecutor { tx, join: Some(join) })
    }

    pub fn handle(&self) -> DeviceHandle {
        DeviceHandle { tx: self.tx.clone() }
    }

    pub fn spec(&self) -> Result<VariantSpec> {
        self.handle().spec()
    }

    pub fn initial_params(&self) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::InitialParams { reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }
}

impl Drop for DeviceExecutor {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl DeviceHandle {
    /// Execute one fused train+SGD step; `params` are updated in place.
    pub fn train(
        &self,
        params: &mut Vec<Vec<f32>>,
        batch: HostBatch,
        lr: f32,
    ) -> Result<f32> {
        self.train_reusing(params, batch, lr).map(|(loss, _)| loss)
    }

    /// Like [`Self::train`], but hands the spent batch back so its
    /// buffers can be recycled (§Perf: feed it to
    /// [`BatchPool::put`](crate::pipeline::BatchPool::put) and the
    /// sampling thread reuses the `n0 × feat_dim` feature allocation).
    pub fn train_reusing(
        &self,
        params: &mut Vec<Vec<f32>>,
        batch: HostBatch,
        lr: f32,
    ) -> Result<(f32, HostBatch)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Train {
                params: std::mem::take(params),
                batch: Box::new(batch),
                lr,
                reply,
            })
            .map_err(|_| anyhow!("executor gone"))?;
        let (p, loss, spent) =
            rx.recv().map_err(|_| anyhow!("executor gone"))??;
        *params = p;
        Ok((loss, *spent))
    }

    pub fn eval(
        &self,
        params: &[Vec<f32>],
        batch: HostBatch,
    ) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Eval {
                params: params.to_vec(),
                batch: Box::new(batch),
                reply,
            })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    pub fn spec(&self) -> Result<VariantSpec> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Spec { reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }
}

fn run_executor(
    artifacts: PathBuf,
    variant: String,
    pcie: Option<Arc<CostModel>>,
    rx: Receiver<Req>,
    ready: Sender<Result<()>>,
) {
    // Share one PJRT client per process: creating many TfrtCpuClients is
    // expensive and they fight over threads.
    let env = match crate::runtime::RuntimeEnv::new(&artifacts) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let exe = match env.load(&variant) {
        Ok(mut e) => {
            e.pcie = pcie;
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));
    while let Ok(req) = rx.recv() {
        match req {
            Req::Train { mut params, batch, lr, reply } => {
                let r = exe
                    .train_step_with(&mut params, &batch, lr)
                    .map(|loss| (params, loss, batch));
                let _ = reply.send(r);
            }
            Req::Eval { params, batch, reply } => {
                let _ = reply.send(exe.eval_step_with(&params, &batch));
            }
            Req::Spec { reply } => {
                let _ = reply.send(Ok(exe.spec.clone()));
            }
            Req::InitialParams { reply } => {
                let _ = reply.send(env.manifest.load_params(&exe.spec));
            }
            Req::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::artifacts_dir;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn executor_serves_multiple_threads() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ex = DeviceExecutor::spawn(
            artifacts_dir(),
            "sage_nc_dev".into(),
            None,
        )
        .unwrap();
        let spec = ex.spec().unwrap();
        let init = ex.initial_params().unwrap();
        let mut handles = Vec::new();
        for t in 0..3u64 {
            let h = ex.handle();
            let spec = spec.clone();
            let mut params = init.clone();
            handles.push(std::thread::spawn(move || {
                // real sampled block structure (never synthesized rels)
                let batch =
                    crate::pipeline::gen::tests_support::sampled_batch(
                        &spec, t,
                    );
                let mut last = f32::INFINITY;
                for _ in 0..3 {
                    last = h.train(&mut params, batch.clone(), 0.3).unwrap();
                }
                assert!(last.is_finite());
                last
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
