//! Distributed synchronous-SGD training (§5.6).
//!
//! Data parallelism: every trainer holds a full dense-parameter replica,
//! consumes mini-batches from its own pipeline, executes the fused-SGD
//! HLO on the device executor, and synchronizes replicas with a ring
//! all-reduce at every iteration boundary (the paper's PyTorch-DDP role).
//! Sparse embedding gradients bypass the ring and go to the KVStore
//! owners (§5.4).

pub mod allreduce;
pub mod device;
pub mod elastic;
pub mod split;

pub use allreduce::{AllReduceError, AllReduceGroup};
pub use device::{DeviceExecutor, DeviceHandle};
pub use elastic::ReconfigStats;
pub use split::{split_training_set, split_training_set_for};

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::{DistGraph, DistNodeDataLoader, Seeds};
use crate::cluster::Cluster;
use crate::coordinator::ResizeEvent;
use crate::ft::Checkpoint;
use crate::metrics::Metrics;
use crate::pipeline::PipelineConfig;
use crate::util::Rng;

/// Training hyper-parameters for one run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub variant: String,
    pub lr: f32,
    pub epochs: usize,
    /// Cap on total steps (0 = epochs * loader length). A cap that is
    /// not a multiple of the per-epoch batch count leaves a short final
    /// epoch window in the report (see [`epoch_windows`]).
    pub max_steps: usize,
    /// Skip each epoch's short tail batch (DGL's `drop_last`); shrinks
    /// the loader length accordingly, which `max_steps = 0` inherits.
    pub drop_last: bool,
    pub pipeline: PipelineConfig,
    pub seed: u64,
    /// Evaluate on the validation set after each epoch.
    pub eval_each_epoch: bool,
    /// Write a full checkpoint every this many global steps, at the
    /// all-reduce barrier (0 = never). Requires `checkpoint_dir`.
    pub checkpoint_every: usize,
    /// Directory receiving `ckpt_<step>.ckpt` files ("" = no
    /// checkpoints).
    pub checkpoint_dir: String,
    /// Path of a checkpoint to resume from ("" = fresh run). The run
    /// restores KV shards + params (and momentum velocity) and replays
    /// the exact batch stream from the saved step (docs/DESIGN.md §8) —
    /// byte-identical to a run that never stopped (test-enforced).
    pub resume_from: String,
    /// SGD momentum coefficient in `[0, 1)`. Applied to the
    /// *post-all-reduce mean* gradient, so the velocity is identical on
    /// every rank and one checkpoint copy restores it; `0.0` is plain
    /// SGD, byte-identical to the pre-momentum trainer.
    pub momentum: f32,
    /// Keep only the newest N checkpoints in `checkpoint_dir`, pruning
    /// older ones (and orphaned `.tmp` files) after each write
    /// (0 = keep everything).
    pub checkpoint_keep: usize,
    /// Planned elastic resize schedule: at cumulative epoch boundary
    /// `boundary`, reshape the membership to `world` trainers
    /// (docs/DESIGN.md §9; config key `elastic = "E:W,..."`). Non-empty
    /// routes the run through the elastic driver.
    pub elastic: Vec<ResizeEvent>,
    /// Demote machines whose mean step time persistently exceeds
    /// `straggler_factor` × the fleet median (measured from per-step
    /// heartbeats). Enables the elastic driver.
    pub demote_stragglers: bool,
    /// Straggler threshold multiplier over the fleet median step time.
    pub straggler_factor: f64,
    /// Consecutive epoch boundaries a machine must straggle before the
    /// coordinator demotes it.
    pub straggler_patience: usize,
    /// A rank silent (no heartbeat, no barrier arrival) this long at an
    /// epoch boundary is declared dead and its machine demoted.
    pub heartbeat_timeout: Duration,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            variant: "sage_nc_dev".into(),
            lr: 0.3,
            epochs: 2,
            max_steps: 0,
            drop_last: false,
            pipeline: PipelineConfig::default(),
            seed: 7,
            eval_each_epoch: false,
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
            resume_from: String::new(),
            momentum: 0.0,
            checkpoint_keep: 0,
            elastic: Vec::new(),
            demote_stragglers: false,
            straggler_factor: 3.0,
            straggler_patience: 2,
            heartbeat_timeout: Duration::from_secs(5),
        }
    }
}

impl TrainConfig {
    /// Whether this run needs the elastic driver: a planned resize
    /// schedule or straggler demotion (the classic fixed-membership
    /// loop stays byte-identical otherwise).
    pub fn is_elastic(&self) -> bool {
        !self.elastic.is_empty() || self.demote_stragglers
    }
}

/// Per-epoch record in the final report.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_loss: f64,
    pub secs: f64,
    pub val_acc: Option<f64>,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub epochs: Vec<EpochStats>,
    pub total_secs: f64,
    pub steps: usize,
    /// Loss per global step (mean across trainers).
    pub loss_curve: Vec<f32>,
    pub net_bytes: u64,
    pub pcie_bytes: u64,
    pub remote_feature_rows: u64,
    /// FeatureCache counters aggregated across trainers (0 when the
    /// cache is disabled). Metered at batch *production*: in the
    /// non-stop pipeline they include the few prefetched batches the
    /// teardown never trains on, so compare them with
    /// `remote_feature_rows` (consumed-side) only qualitatively.
    pub cache_hit_rows: u64,
    pub cache_miss_rows: u64,
    pub cache_remote_bytes_saved: u64,
    /// Predictive-prefetcher counters (docs/DESIGN.md §10), same
    /// production-side accounting as the other `cache.*` fields; all
    /// zero with `prefetch_depth = 0`. `wasted` bytes are prefetched
    /// rows evicted or invalidated before any demand hit — the
    /// lookahead's false-positive cost.
    pub cache_prefetch_issued: u64,
    pub cache_prefetch_hits: u64,
    pub cache_prefetch_wasted_bytes: u64,
    /// Cumulative pin events on imminent-batch rows (each demand hit
    /// releases one pin; see `CacheStats::pinned_rows`).
    pub cache_pinned_rows: u64,
    /// Neighbors dropped by layer budget caps, across trainers
    /// (consumed batches, same accounting as `remote_feature_rows`).
    pub dropped_neighbors: u64,
    /// Sampled (kept) edges per etype across trainers, from the
    /// `sampler.etype_edges.*` counters; empty on homogeneous runs.
    /// Production-side accounting, like the `cache.*` counters.
    pub etype_sampled_edges: Vec<u64>,
    /// BatchPool recycling counters across trainers (production-side
    /// accounting, like `cache.*`): takes served from the pool, takes
    /// that allocated fresh, and returns discarded because the pool was
    /// full (a persistent `pool.dropped` stream means the pool cap is
    /// too small for the worker count / prefetch depth).
    pub pool_hit: u64,
    pub pool_miss: u64,
    pub pool_dropped: u64,
    pub final_val_acc: Option<f64>,
    /// Aggregate stage 1-4 CPU time across all trainers and sampling
    /// workers (for the pipeline model used by the benches — DESIGN.md
    /// §2): the sum of the four per-stage timers below.
    pub sample_secs: f64,
    /// Per-stage breakdown of `sample_secs` (`pipeline.schedule` /
    /// `pipeline.sample` / `pipeline.pull` / `pipeline.compact`),
    /// aggregated across workers.
    pub stage_schedule_secs: f64,
    pub stage_sample_secs: f64,
    pub stage_pull_secs: f64,
    pub stage_compact_secs: f64,
    /// CPU time spent in the background prefetch thread
    /// (`pipeline.prefetch`). Deliberately *not* part of `sample_secs`:
    /// the lookahead overlaps the demand stages, so adding it would
    /// double-count wall clock in the pipeline model.
    pub stage_prefetch_secs: f64,
    /// Batches actually produced by the sampling workers (non-stop mode
    /// overproduces; unit-cost calibration must divide by this).
    pub batches_produced: u64,
    pub device_secs: f64,
    pub allreduce_secs: f64,
    pub wait_secs: f64,
    /// Fault-tolerance counters (docs/DESIGN.md §8); all zero on an
    /// undisturbed, checkpoint-free run.
    pub ft_checkpoints: u64,
    pub ft_checkpoint_bytes: u64,
    /// RPC retries spent healing transient injected outages.
    pub ft_retries: u64,
    /// Injected KV/sampler failures admitted by the fault plan.
    pub ft_injected_failures: u64,
    /// Wall-clock seconds loading + restoring the resume checkpoint
    /// (0.0 on a fresh run).
    pub ft_recovery_secs: f64,
    /// Global step this run resumed from (0 = fresh run); `steps`
    /// counts only the steps executed *this* run.
    pub resumed_at: u64,
    /// Membership reconfigurations executed by the elastic driver
    /// (docs/DESIGN.md §9), one per published membership epoch, with
    /// the cost decomposition (drain / checkpoint / re-split / warmup).
    /// Empty on classic fixed-membership runs.
    pub reconfigurations: Vec<ReconfigStats>,
    /// `reconfigurations.len()`, also exported as the
    /// `ft.reconfigurations` counter.
    pub ft_reconfigurations: u64,
    /// Machines removed from the membership by failure or straggler
    /// demotion (planned resizes are not demotions); the
    /// `ft.demotions` counter.
    pub ft_demotions: u64,
    /// Primaries failed over to their standby replica (docs/DESIGN.md
    /// §12); the `ft.failovers` counter. 0 without `replicate_kv`.
    pub ft_failovers: u64,
    /// Restarted servers that re-imported their shards and flipped
    /// back to primary; the `ft.rejoins` counter.
    pub ft_rejoins: u64,
    /// Bytes copied into replica tables (deploy materialization plus
    /// rejoin re-imports); the `ft.replica_bytes` counter.
    pub ft_replica_bytes: u64,
    /// Final synchronized parameters.
    pub final_params: Vec<Vec<f32>>,
}

impl TrainReport {
    /// Per-(global)step mean of a stage time across trainers.
    pub fn per_step(&self, total: f64, n_trainers: usize) -> f64 {
        total / (self.steps.max(1) * n_trainers.max(1)) as f64
    }
}

/// Run synchronous data-parallel training on a deployed cluster.
///
/// A thin client of the public `api` surface: one
/// [`DistNodeDataLoader`] per trainer rank drains the asynchronous
/// pipeline exactly as any hand-written loop would
/// (`examples/custom_loop.rs` is the open-coded equivalent — same
/// batches, byte for byte). Spawns one trainer thread per (machine,
/// trainer-slot); each consumes its own loader and participates in the
/// ring all-reduce; a device executor per machine serializes device
/// compute (this testbed has one physical core — device *scaling* is
/// reported via the cost model).
pub fn train(cluster: &Cluster, cfg: &TrainConfig) -> anyhow::Result<TrainReport> {
    anyhow::ensure!(
        (0.0..1.0).contains(&cfg.momentum),
        "momentum {} outside [0, 1)",
        cfg.momentum
    );
    if cfg.is_elastic() {
        // coordinator-driven membership: epoch-boundary barriers,
        // re-splits, and reconfiguration live in their own driver; the
        // classic loop below stays byte-identical for fixed-membership
        // runs
        return elastic::train_elastic(cluster, cfg);
    }
    let n_trainers = cluster.n_trainers();
    let metrics = Arc::new(Metrics::new());

    // Device executors (one per machine), compile once.
    let mut devices = Vec::with_capacity(cluster.spec.n_machines);
    for _ in 0..cluster.spec.n_machines {
        devices.push(DeviceExecutor::spawn(
            cluster.artifacts.clone(),
            cfg.variant.clone(),
            Some(cluster.cost.clone()),
        )?);
    }
    let mut init_params = devices[0].initial_params()?;
    let spec = devices[0].spec()?;
    // graceful form of the batch_gen invariant: an RGCN variant must
    // cover every relation the deployed schema can sample
    anyhow::ensure!(
        spec.model != crate::sampler::compact::ModelKind::Rgcn
            || spec.num_rels >= cluster.schema.n_etypes(),
        "variant {:?} compiled for {} relations but the deployed schema \
         declares {} etypes — use the matching artifact (e.g. \
         rgcn_nc_mag) or align the dataset with num_rels=<n>",
        spec.name,
        spec.num_rels,
        cluster.schema.n_etypes()
    );

    // Exact resume (docs/DESIGN.md §8): restore every KVStore shard and
    // the synchronized params from the snapshot, then restart every
    // loader at the saved global step — batch composition is a pure
    // function of (seed, step), so the replayed stream is byte-identical
    // to the one a never-interrupted run consumes.
    let mut start_step = 0usize;
    let mut ft_recovery_secs = 0.0f64;
    let mut init_velocity: Vec<Vec<f32>> = Vec::new();
    if !cfg.resume_from.is_empty() {
        let t_rec = Instant::now();
        let ck = Checkpoint::load(Path::new(&cfg.resume_from))?;
        anyhow::ensure!(
            ck.seed == cfg.seed,
            "checkpoint {} was written by a run with seed {}, this run \
             uses {} — the replayed stream would differ",
            cfg.resume_from,
            ck.seed,
            cfg.seed
        );
        anyhow::ensure!(
            ck.momentum == cfg.momentum,
            "checkpoint {} was written with momentum {}, this run uses \
             {} — the resumed optimizer state would be inconsistent",
            cfg.resume_from,
            ck.momentum,
            cfg.momentum
        );
        ck.restore(&cluster.kv.servers)?;
        start_step = ck.step as usize;
        init_params = ck.params;
        init_velocity = ck.velocity;
        ft_recovery_secs = t_rec.elapsed().as_secs_f64();
    }

    // All-reduce plane: one endpoint per trainer.
    let machine_of: Vec<u32> = (0..n_trainers)
        .map(|t| (t / cluster.spec.trainers_per_machine) as u32)
        .collect();
    let ar = AllReduceGroup::new(machine_of.clone(), cluster.cost.clone());

    // One data loader per trainer rank through the public API — the same
    // construction any custom loop performs; all pipeline/BatchGen wiring
    // lives behind the loader.
    let graph = DistGraph::new(cluster);
    let mut loaders: Vec<DistNodeDataLoader> =
        Vec::with_capacity(n_trainers);
    for t in 0..n_trainers {
        loaders.push(
            DistNodeDataLoader::builder(&graph, &spec)
                .rank(t)
                .seeds(Seeds::Train)
                .drop_last(cfg.drop_last)
                .seed(cfg.seed ^ (t as u64) << 17)
                .start_at(start_step as u64)
                .pipeline(cfg.pipeline.clone())
                .metrics(metrics.clone())
                .build()?,
        );
    }
    // synchronous SGD: the splits are trimmed to equal counts at deploy,
    // so every rank's loader agrees on the epoch length
    let steps_per_epoch = loaders[0].len();
    let total_steps = if cfg.max_steps > 0 {
        cfg.max_steps
    } else {
        cfg.epochs * steps_per_epoch
    };
    anyhow::ensure!(
        start_step < total_steps,
        "resume step {start_step} is not before the run's last step \
         {total_steps} — nothing left to train"
    );
    let run_steps = total_steps - start_step;

    let cost0 = cluster.cost.snapshot();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (t, mut loader) in loaders.into_iter().enumerate() {
        let machine = machine_of[t];
        let device = devices[machine as usize].handle();
        let ep = ar.endpoint(t)?;
        let mut params = init_params.clone();
        let mut velocity = init_velocity.clone();
        let lr = cfg.lr;
        let momentum = cfg.momentum;
        let metrics = metrics.clone();
        // rank 0 writes checkpoints at the barrier: params are
        // synchronized there, and the KV tables are read-only during
        // training, so the snapshot is consistent
        let write_ckpt = t == 0
            && cfg.checkpoint_every > 0
            && !cfg.checkpoint_dir.is_empty();
        let ckpt_every = cfg.checkpoint_every.max(1);
        let ckpt_dir = cfg.checkpoint_dir.clone();
        let ckpt_keep = cfg.checkpoint_keep;
        let ckpt_seed = cfg.seed;
        let servers = cluster.kv.servers.clone();
        handles.push(std::thread::spawn(
            move || -> anyhow::Result<(Vec<f32>, Vec<Vec<f32>>)> {
                let mut losses = Vec::with_capacity(run_steps);
                let mut prev: Vec<Vec<f32>> = Vec::new();
                for step in start_step..total_steps {
                    let batch = metrics.time("trainer.wait_batch", || {
                        loader.try_next_batch()
                    })?;
                    metrics
                        .inc("trainer.remote_rows", batch.remote_rows as u64);
                    metrics.inc(
                        "trainer.dropped_nbrs",
                        batch.dropped_neighbors as u64,
                    );
                    if momentum > 0.0 {
                        // pre-step replica (rank-identical) — the
                        // momentum update derives the mean gradient
                        // from it after the all-reduce
                        prev.clone_from(&params);
                    }
                    let (loss, spent) =
                        metrics.time("trainer.device", || {
                            device.train_reusing(&mut params, batch, lr)
                        })?;
                    // spent batches flow back to the sampling thread's
                    // BatchGen through the loader's pool (§Perf)
                    loader.recycle(spent);
                    losses.push(loss);
                    // synchronous SGD barrier: average replicas
                    metrics.time("trainer.allreduce", || {
                        ep.allreduce_params(&mut params)
                    })?;
                    if momentum > 0.0 {
                        apply_momentum(
                            &mut params,
                            &prev,
                            &mut velocity,
                            momentum,
                            lr,
                        );
                    }
                    if write_ckpt && (step + 1) % ckpt_every == 0 {
                        let at = (step + 1) as u64;
                        let ck = Checkpoint::capture(
                            ckpt_seed, at, &params, &servers,
                        )
                        .with_optimizer(momentum, velocity.clone());
                        let bytes = ck.save(&Checkpoint::path_for(
                            Path::new(&ckpt_dir),
                            at,
                        ))?;
                        Checkpoint::prune(
                            Path::new(&ckpt_dir),
                            ckpt_keep,
                        )?;
                        metrics.inc("ft.checkpoints", 1);
                        metrics.inc("ft.checkpoint_bytes", bytes);
                    }
                }
                Ok((losses, params))
            },
        ));
    }

    let mut curves: Vec<Vec<f32>> = Vec::new();
    let mut final_params: Vec<Vec<f32>> = init_params.clone();
    for h in handles {
        let (losses, params) = h.join().expect("trainer thread panicked")?;
        curves.push(losses);
        final_params = params;
    }
    let total_secs = t0.elapsed().as_secs_f64();
    let cost1 = cluster.cost.snapshot();
    let delta = cost0.delta(&cost1);

    // mean loss across trainers per executed step (a resumed run's
    // curve starts at `start_step`; index 0 is that step's loss)
    let loss_curve: Vec<f32> = (0..run_steps)
        .map(|s| {
            curves.iter().map(|c| c[s]).sum::<f32>() / n_trainers as f32
        })
        .collect();

    // epoch aggregation + optional eval — windows are laid out over the
    // *global* step axis, then clipped to what this run executed
    let mut epochs = Vec::new();
    let mut final_val_acc = None;
    for (e, (lo, hi)) in
        epoch_windows(steps_per_epoch, total_steps).into_iter().enumerate()
    {
        let lo = lo.max(start_step);
        if lo >= hi {
            continue; // fully replayed by the checkpoint
        }
        let mean_loss = loss_curve[lo - start_step..hi - start_step]
            .iter()
            .map(|&x| x as f64)
            .sum::<f64>()
            / (hi - lo) as f64;
        epochs.push(EpochStats {
            epoch: e,
            mean_loss,
            secs: total_secs * (hi - lo) as f64 / run_steps as f64,
            val_acc: None,
        });
    }
    if cfg.eval_each_epoch {
        // validation accuracy with the synchronized final params (all
        // replicas are identical after the last all-reduce)
        final_val_acc = Some(cluster.evaluate(
            &devices[0].handle(),
            &spec,
            &final_params,
            cfg.seed,
        )?);
    }

    // injected-fault accounting (retries, admitted failures, message
    // drops/delays) flows into the same metrics sink as everything else
    if let Some(plan) = cluster.fault_plan() {
        plan.publish(&metrics);
    }
    // replication accounting (failovers, rejoins, replica bytes and the
    // pipeline.failover timer) rides along when replicate_kv is on
    if let Some(rs) = cluster.kv.replica_set() {
        rs.publish(&metrics);
    }

    Ok(TrainReport::from_metrics(
        &metrics,
        epochs,
        total_secs,
        run_steps,
        loss_curve,
        delta.net_bytes,
        delta.pcie_bytes,
        final_val_acc,
        ft_recovery_secs,
        start_step as u64,
        final_params,
        Vec::new(),
    ))
}

impl TrainReport {
    /// Assemble a report from the metrics sink plus the pieces only the
    /// driver knows (curves, wall clock, final params). Shared by the
    /// classic and elastic drivers so counter accounting stays
    /// consistent between them.
    #[allow(clippy::too_many_arguments)]
    fn from_metrics(
        metrics: &Metrics,
        epochs: Vec<EpochStats>,
        total_secs: f64,
        steps: usize,
        loss_curve: Vec<f32>,
        net_bytes: u64,
        pcie_bytes: u64,
        final_val_acc: Option<f64>,
        ft_recovery_secs: f64,
        resumed_at: u64,
        final_params: Vec<Vec<f32>>,
        reconfigurations: Vec<ReconfigStats>,
    ) -> TrainReport {
        // per-etype sampled-edge counters (suffix after the prefix is
        // the etype index)
        let etype_prefix = "sampler.etype_edges.";
        let mut etype_sampled_edges: Vec<u64> = Vec::new();
        for (k, c) in metrics.counters_with_prefix(etype_prefix) {
            if let Ok(r) = k[etype_prefix.len()..].parse::<usize>() {
                if etype_sampled_edges.len() <= r {
                    etype_sampled_edges.resize(r + 1, 0);
                }
                etype_sampled_edges[r] = c;
            }
        }
        TrainReport {
            epochs,
            total_secs,
            steps,
            loss_curve,
            net_bytes,
            pcie_bytes,
            remote_feature_rows: metrics.counter("trainer.remote_rows"),
            cache_hit_rows: metrics.counter("cache.hit_rows"),
            cache_miss_rows: metrics.counter("cache.miss_rows"),
            cache_remote_bytes_saved: metrics
                .counter("cache.remote_bytes_saved"),
            cache_prefetch_issued: metrics
                .counter("cache.prefetch_issued"),
            cache_prefetch_hits: metrics.counter("cache.prefetch_hits"),
            cache_prefetch_wasted_bytes: metrics
                .counter("cache.prefetch_wasted_bytes"),
            cache_pinned_rows: metrics.counter("cache.pinned_rows"),
            dropped_neighbors: metrics.counter("trainer.dropped_nbrs"),
            etype_sampled_edges,
            pool_hit: metrics.counter("pool.hit"),
            pool_miss: metrics.counter("pool.miss"),
            pool_dropped: metrics.counter("pool.dropped"),
            final_val_acc,
            sample_secs: ["schedule", "sample", "pull", "compact"]
                .iter()
                .map(|s| {
                    metrics
                        .total_time(&format!("pipeline.{s}"))
                        .as_secs_f64()
                })
                .sum(),
            stage_schedule_secs: metrics
                .total_time("pipeline.schedule")
                .as_secs_f64(),
            stage_sample_secs: metrics
                .total_time("pipeline.sample")
                .as_secs_f64(),
            stage_pull_secs: metrics
                .total_time("pipeline.pull")
                .as_secs_f64(),
            stage_compact_secs: metrics
                .total_time("pipeline.compact")
                .as_secs_f64(),
            stage_prefetch_secs: metrics
                .total_time("pipeline.prefetch")
                .as_secs_f64(),
            batches_produced: metrics.counter("pipeline.batches"),
            device_secs: metrics.total_time("trainer.device").as_secs_f64(),
            allreduce_secs: metrics
                .total_time("trainer.allreduce")
                .as_secs_f64(),
            wait_secs: metrics
                .total_time("trainer.wait_batch")
                .as_secs_f64(),
            ft_checkpoints: metrics.counter("ft.checkpoints"),
            ft_checkpoint_bytes: metrics.counter("ft.checkpoint_bytes"),
            ft_retries: metrics.counter("ft.retries"),
            ft_injected_failures: metrics.counter("ft.injected_failures"),
            ft_recovery_secs,
            resumed_at,
            ft_reconfigurations: metrics.counter("ft.reconfigurations"),
            ft_demotions: metrics.counter("ft.demotions"),
            ft_failovers: metrics.counter("ft.failovers"),
            ft_rejoins: metrics.counter("ft.rejoins"),
            ft_replica_bytes: metrics.counter("ft.replica_bytes"),
            reconfigurations,
            final_params,
        }
    }
}

/// Momentum SGD over the *post-all-reduce mean* gradient: the device
/// step applied `p = prev − lr·g_local` per rank, the all-reduce
/// averaged the replicas to `p_avg = prev − lr·mean(g)`, so
/// `g = (prev − p_avg)/lr` recovers the mean gradient exactly. Because
/// `prev` and `p_avg` are rank-identical, the velocity is too — one
/// checkpoint copy restores every rank (and zombie ranks can apply the
/// same update without having stepped). Velocity buffers are allocated
/// lazily on first use.
fn apply_momentum(
    params: &mut [Vec<f32>],
    prev: &[Vec<f32>],
    velocity: &mut Vec<Vec<f32>>,
    momentum: f32,
    lr: f32,
) {
    if velocity.is_empty() {
        *velocity =
            params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    }
    for ((p, q), v) in
        params.iter_mut().zip(prev).zip(velocity.iter_mut())
    {
        for ((pi, &qi), vi) in
            p.iter_mut().zip(q).zip(v.iter_mut())
        {
            let g = (qi - *pi) / lr;
            *vi = momentum * *vi + g;
            *pi = qi - lr * *vi;
        }
    }
}

/// Deterministic mean of per-trainer RNG streams (used in tests).
pub fn mix_seed(seed: u64, t: usize) -> u64 {
    let mut r = Rng::new(seed);
    r.split(t as u64).next_u64()
}

/// Closed-open step windows `[lo, hi)` attributing every step of a
/// `max_steps`-capped run to an epoch: full windows of
/// `steps_per_epoch`, with one short final window when the cap falls
/// inside an epoch. Unlike the pre-loader aggregation (which silently
/// dropped steps beyond `epochs * steps_per_epoch`), every step lands in
/// exactly one window — the loader's `len()` (which already accounts for
/// `drop_last` and the trimmed multi-trainer split) is the
/// `steps_per_epoch` to pass.
pub fn epoch_windows(
    steps_per_epoch: usize,
    total_steps: usize,
) -> Vec<(usize, usize)> {
    let spe = steps_per_epoch.max(1);
    (0..total_steps.div_ceil(spe))
        .map(|e| (e * spe, ((e + 1) * spe).min(total_steps)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_windows_partition_every_step() {
        // regression for the epoch-boundary off-by-one: a max_steps cap
        // one past an epoch boundary must open a 1-step final window,
        // and a cap exactly on the boundary must not open an empty one
        assert_eq!(epoch_windows(5, 11), vec![(0, 5), (5, 10), (10, 11)]);
        assert_eq!(epoch_windows(5, 10), vec![(0, 5), (5, 10)]);
        assert_eq!(epoch_windows(5, 4), vec![(0, 4)]);
        assert_eq!(epoch_windows(5, 0), Vec::<(usize, usize)>::new());
        // drop_last shrinks the per-epoch count; the windows follow it
        assert_eq!(epoch_windows(4, 9), vec![(0, 4), (4, 8), (8, 9)]);
        for (spe, total) in [(1usize, 7usize), (3, 7), (7, 7), (16, 7)] {
            let w = epoch_windows(spe, total);
            assert_eq!(w[0].0, 0);
            assert_eq!(w.last().unwrap().1, total);
            for pair in w.windows(2) {
                assert_eq!(pair[0].1, pair[1].0, "windows must be contiguous");
            }
            assert!(w.iter().all(|&(lo, hi)| lo < hi), "no empty windows");
        }
    }

    #[test]
    fn epoch_windows_survive_degenerate_epoch_len() {
        // steps_per_epoch 0 (empty split) must not divide by zero
        assert_eq!(epoch_windows(0, 3), vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn momentum_recovers_the_mean_gradient_and_accumulates() {
        let lr = 0.5f32;
        let momentum = 0.9f32;
        // the device step moved p from 1.0 to 0.5 at lr 0.5, i.e. the
        // (post-all-reduce mean) gradient was (1.0 - 0.5)/0.5 = 1.0; the
        // second coordinate saw zero gradient
        let prev = vec![vec![1.0f32, 2.0]];
        let mut params = vec![vec![0.5f32, 2.0]];
        let mut velocity: Vec<Vec<f32>> = Vec::new();
        apply_momentum(&mut params, &prev, &mut velocity, momentum, lr);
        assert_eq!(velocity, vec![vec![1.0f32, 0.0]]);
        // first step: velocity == gradient, so the update equals plain
        // SGD — params must be untouched
        assert_eq!(params, vec![vec![0.5f32, 2.0]]);
        // second step with the same observed gradient: velocity
        // accumulates (0.9 * 1.0 + 1.0) and the update overshoots the
        // plain-SGD step accordingly
        let prev2 = params.clone();
        params[0][0] = 0.0; // (0.5 - 0.0)/0.5 = gradient 1.0 again
        apply_momentum(&mut params, &prev2, &mut velocity, momentum, lr);
        assert!((velocity[0][0] - 1.9).abs() < 1e-6, "{velocity:?}");
        assert!(
            (params[0][0] - (0.5 - 0.5 * 1.9)).abs() < 1e-6,
            "{params:?}"
        );
        assert_eq!(params[0][1], 2.0);
    }
}
