//! Training-set split (§5.6.1): divide the training items across trainers
//! so that (a) every trainer gets *exactly* the same number of items
//! (synchronous SGD needs identical batch counts), (b) items stay
//! co-located with their owning machine's graph partition wherever
//! possible, and (c) the unavoidable remainder of remote items is spread
//! evenly.
//!
//! Because relabeling (§5.3) makes each partition's IDs contiguous, the
//! paper's "assign ID ranges to the machine with the largest overlap" is
//! implemented directly: training IDs are sorted (= grouped by owner),
//! cut into `n_trainers` equal ranges, and each range lands on the machine
//! owning most of it.

use crate::graph::NodeId;
use crate::partition::NodeMap;

/// Split `train_ids` (new global IDs) into `n_machines * per_machine`
/// equal-size sets. Returns `sets[t]` for trainer `t` (machine-major
/// order: trainer t lives on machine `t / per_machine`).
pub fn split_training_set(
    mut train_ids: Vec<NodeId>,
    node_map: &NodeMap,
    n_machines: usize,
    per_machine: usize,
) -> Vec<Vec<NodeId>> {
    let n_trainers = n_machines * per_machine;
    assert!(n_trainers > 0);
    train_ids.sort_unstable(); // contiguous ranges ⇒ grouped by owner
    let total = train_ids.len();
    let base = total / n_trainers;
    let rem = total % n_trainers;

    // equal-size contiguous ranges (first `rem` get one extra)
    let mut ranges: Vec<&[NodeId]> = Vec::with_capacity(n_trainers);
    let mut off = 0usize;
    for t in 0..n_trainers {
        let len = base + usize::from(t < rem);
        ranges.push(&train_ids[off..off + len]);
        off += len;
    }

    // majority owner of each range
    let majority = |ids: &[NodeId]| -> u32 {
        if ids.is_empty() {
            return 0;
        }
        let mut counts = vec![0usize; n_machines];
        for &id in ids {
            let o = node_map.owner(id) as usize;
            counts[o.min(n_machines - 1)] += 1;
        }
        counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(m, _)| m as u32)
            .unwrap()
    };

    // assign ranges to machines: prefer majority owner, but cap each
    // machine at `per_machine` ranges so every trainer gets exactly one
    let mut machine_load = vec![0usize; n_machines];
    let mut assignment: Vec<Option<u32>> = vec![None; n_trainers];
    // first pass: happy path
    for (i, r) in ranges.iter().enumerate() {
        let m = majority(r) as usize;
        if machine_load[m] < per_machine {
            machine_load[m] += 1;
            assignment[i] = Some(m as u32);
        }
    }
    // second pass: spill the rest to the least-loaded machines (these are
    // the "remote training points", balanced evenly per the paper)
    for slot in assignment.iter_mut() {
        if slot.is_none() {
            let m = (0..n_machines)
                .min_by_key(|&m| machine_load[m])
                .unwrap();
            machine_load[m] += 1;
            *slot = Some(m as u32);
        }
    }

    // order sets machine-major so trainer t = machine t/per_machine
    let mut out: Vec<Vec<NodeId>> = vec![Vec::new(); n_trainers];
    let mut next_slot = vec![0usize; n_machines];
    for (i, r) in ranges.iter().enumerate() {
        let m = assignment[i].unwrap() as usize;
        let t = m * per_machine + next_slot[m];
        next_slot[m] += 1;
        out[t] = r.to_vec();
    }
    out
}

/// Fraction of a trainer's items owned by its own machine (locality
/// observability; the paper's design keeps this near 1.0).
pub fn locality(
    sets: &[Vec<NodeId>],
    node_map: &NodeMap,
    per_machine: usize,
) -> f64 {
    let mut local = 0usize;
    let mut total = 0usize;
    for (t, set) in sets.iter().enumerate() {
        let m = (t / per_machine) as u32;
        for &id in set {
            total += 1;
            if node_map.owner(id) == m {
                local += 1;
            }
        }
    }
    local as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{
        metis_partition, relabel, PartitionConfig, VertexWeights,
    };

    fn setup(n_machines: usize) -> (Vec<NodeId>, NodeMap) {
        let spec = DatasetSpec::new("sp", 2000, 8000);
        let d = spec.generate();
        let vw = VertexWeights::for_training(
            d.n_nodes(),
            &d.split,
            &d.graph.node_type,
            1,
        );
        let p = metis_partition(
            &d.graph,
            &vw,
            &PartitionConfig::new(n_machines),
        );
        let r = relabel::relabel(&p);
        let d2 = relabel::relabel_dataset(&d, &r);
        let train: Vec<NodeId> = d2
            .nodes_with(crate::graph::SplitTag::Train);
        (train, r.node_map)
    }

    #[test]
    fn counts_are_equal_and_cover_everything() {
        let (train, nm) = setup(3);
        let sets = split_training_set(train.clone(), &nm, 3, 2);
        assert_eq!(sets.len(), 6);
        let max = sets.iter().map(|s| s.len()).max().unwrap();
        let min = sets.iter().map(|s| s.len()).min().unwrap();
        assert!(max - min <= 1, "sizes {:?}", sets.iter().map(|s| s.len()).collect::<Vec<_>>());
        let mut all: Vec<NodeId> =
            sets.iter().flatten().copied().collect();
        all.sort_unstable();
        let mut expect = train;
        expect.sort_unstable();
        assert_eq!(all, expect);
    }

    #[test]
    fn locality_is_high_with_metis_partitions() {
        let (train, nm) = setup(4);
        let sets = split_training_set(train, &nm, 4, 2);
        let loc = locality(&sets, &nm, 2);
        assert!(loc > 0.7, "locality {loc}");
    }

    #[test]
    fn single_trainer_gets_everything() {
        let (train, nm) = setup(1);
        let sets = split_training_set(train.clone(), &nm, 1, 1);
        assert_eq!(sets.len(), 1);
        assert_eq!(sets[0].len(), train.len());
    }

    /// Property: any (machines, per_machine) split is total and balanced.
    #[test]
    fn prop_split_total_and_balanced() {
        let (train, nm) = setup(4);
        crate::util::proptest::forall(
            51,
            12,
            |r| (1 + r.usize_below(4), 1 + r.usize_below(4)),
            |&(m, per)| {
                let m = m.min(nm.nparts());
                let sets =
                    split_training_set(train.clone(), &nm, m, per);
                if sets.len() != m * per {
                    return Err("wrong set count".into());
                }
                let total: usize = sets.iter().map(|s| s.len()).sum();
                if total != train.len() {
                    return Err(format!(
                        "lost items: {total} != {}",
                        train.len()
                    ));
                }
                let max = sets.iter().map(|s| s.len()).max().unwrap();
                let min = sets.iter().map(|s| s.len()).min().unwrap();
                if max - min > 1 {
                    return Err(format!("unbalanced: {min}..{max}"));
                }
                Ok(())
            },
        );
    }
}
