//! Training-set split (§5.6.1): divide the training items across trainers
//! so that (a) every trainer gets *exactly* the same number of items
//! (synchronous SGD needs identical batch counts), (b) items stay
//! co-located with their owning machine's graph partition wherever
//! possible, and (c) the unavoidable remainder of remote items is spread
//! evenly.
//!
//! Because relabeling (§5.3) makes each partition's IDs contiguous, the
//! paper's "assign ID ranges to the machine with the largest overlap" is
//! implemented directly: training IDs are sorted (= grouped by owner),
//! cut into `n_trainers` equal ranges, and each range lands on the machine
//! owning most of it.

use crate::graph::NodeId;
use crate::partition::NodeMap;

/// Split `train_ids` (new global IDs) into `n_machines * per_machine`
/// equal-size sets. Returns `sets[t]` for trainer `t` (machine-major
/// order: trainer t lives on machine `t / per_machine`).
pub fn split_training_set(
    train_ids: Vec<NodeId>,
    node_map: &NodeMap,
    n_machines: usize,
    per_machine: usize,
) -> Vec<Vec<NodeId>> {
    let machines: Vec<u32> = (0..n_machines as u32).collect();
    split_training_set_for(train_ids, node_map, &machines, per_machine)
}

/// Membership-aware split: divide `train_ids` across an arbitrary set of
/// surviving `machines` (elastic reconfiguration, docs/DESIGN.md §9).
/// Items owned by demoted machines count toward the last surviving
/// member, mirroring the owner clamp of the contiguous case, and the
/// spill pass rebalances as usual.
///
/// This is a *pure* function of `(train_ids, node_map, machines,
/// per_machine)` — nothing about the previous membership, the order
/// ranks left, or wall-clock time enters — which is what lets every
/// survivor of a membership change recompute its share independently
/// and agree byte-for-byte (test-enforced below). With the full machine
/// list it reduces exactly to [`split_training_set`].
pub fn split_training_set_for(
    mut train_ids: Vec<NodeId>,
    node_map: &NodeMap,
    machines: &[u32],
    per_machine: usize,
) -> Vec<Vec<NodeId>> {
    let n_members = machines.len();
    let n_trainers = n_members * per_machine;
    assert!(n_trainers > 0, "membership must keep at least one trainer");
    train_ids.sort_unstable(); // contiguous ranges ⇒ grouped by owner
    let total = train_ids.len();
    let base = total / n_trainers;
    let rem = total % n_trainers;

    // equal-size contiguous ranges (first `rem` get one extra)
    let mut ranges: Vec<&[NodeId]> = Vec::with_capacity(n_trainers);
    let mut off = 0usize;
    for t in 0..n_trainers {
        let len = base + usize::from(t < rem);
        ranges.push(&train_ids[off..off + len]);
        off += len;
    }

    // membership slot of an owner machine; owners outside the current
    // membership land on the last member (rebalanced by the spill pass)
    let member_of = |owner: u32| -> usize {
        machines
            .iter()
            .position(|&m| m == owner)
            .unwrap_or(n_members - 1)
    };

    // majority member of each range
    let majority = |ids: &[NodeId]| -> usize {
        if ids.is_empty() {
            return 0;
        }
        let mut counts = vec![0usize; n_members];
        for &id in ids {
            counts[member_of(node_map.owner(id))] += 1;
        }
        counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(m, _)| m)
            .unwrap()
    };

    // assign ranges to members: prefer majority owner, but cap each
    // member at `per_machine` ranges so every trainer gets exactly one
    let mut machine_load = vec![0usize; n_members];
    let mut assignment: Vec<Option<usize>> = vec![None; n_trainers];
    // first pass: happy path
    for (i, r) in ranges.iter().enumerate() {
        let m = majority(r);
        if machine_load[m] < per_machine {
            machine_load[m] += 1;
            assignment[i] = Some(m);
        }
    }
    // second pass: spill the rest to the least-loaded members (these are
    // the "remote training points", balanced evenly per the paper)
    for slot in assignment.iter_mut() {
        if slot.is_none() {
            let m = (0..n_members)
                .min_by_key(|&m| machine_load[m])
                .unwrap();
            machine_load[m] += 1;
            *slot = Some(m);
        }
    }

    // order sets member-major so trainer t = member t/per_machine
    let mut out: Vec<Vec<NodeId>> = vec![Vec::new(); n_trainers];
    let mut next_slot = vec![0usize; n_members];
    for (i, r) in ranges.iter().enumerate() {
        let m = assignment[i].unwrap();
        let t = m * per_machine + next_slot[m];
        next_slot[m] += 1;
        out[t] = r.to_vec();
    }
    out
}

/// Fraction of a trainer's items owned by its own machine (locality
/// observability; the paper's design keeps this near 1.0).
pub fn locality(
    sets: &[Vec<NodeId>],
    node_map: &NodeMap,
    per_machine: usize,
) -> f64 {
    let mut local = 0usize;
    let mut total = 0usize;
    for (t, set) in sets.iter().enumerate() {
        let m = (t / per_machine) as u32;
        for &id in set {
            total += 1;
            if node_map.owner(id) == m {
                local += 1;
            }
        }
    }
    local as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{
        metis_partition, relabel, PartitionConfig, VertexWeights,
    };

    fn setup(n_machines: usize) -> (Vec<NodeId>, NodeMap) {
        let spec = DatasetSpec::new("sp", 2000, 8000);
        let d = spec.generate();
        let vw = VertexWeights::for_training(
            d.n_nodes(),
            &d.split,
            &d.graph.node_type,
            1,
        );
        let p = metis_partition(
            &d.graph,
            &vw,
            &PartitionConfig::new(n_machines),
        );
        let r = relabel::relabel(&p);
        let d2 = relabel::relabel_dataset(&d, &r);
        let train: Vec<NodeId> = d2
            .nodes_with(crate::graph::SplitTag::Train);
        (train, r.node_map)
    }

    #[test]
    fn counts_are_equal_and_cover_everything() {
        let (train, nm) = setup(3);
        let sets = split_training_set(train.clone(), &nm, 3, 2);
        assert_eq!(sets.len(), 6);
        let max = sets.iter().map(|s| s.len()).max().unwrap();
        let min = sets.iter().map(|s| s.len()).min().unwrap();
        assert!(max - min <= 1, "sizes {:?}", sets.iter().map(|s| s.len()).collect::<Vec<_>>());
        let mut all: Vec<NodeId> =
            sets.iter().flatten().copied().collect();
        all.sort_unstable();
        let mut expect = train;
        expect.sort_unstable();
        assert_eq!(all, expect);
    }

    #[test]
    fn locality_is_high_with_metis_partitions() {
        let (train, nm) = setup(4);
        let sets = split_training_set(train, &nm, 4, 2);
        let loc = locality(&sets, &nm, 2);
        assert!(loc > 0.7, "locality {loc}");
    }

    #[test]
    fn single_trainer_gets_everything() {
        let (train, nm) = setup(1);
        let sets = split_training_set(train.clone(), &nm, 1, 1);
        assert_eq!(sets.len(), 1);
        assert_eq!(sets[0].len(), train.len());
    }

    /// Property: any membership transition `(machines, per_machine)` →
    /// `(machines', per_machine')` re-split is total (no item lost or
    /// invented), balanced within one item, and a pure function of the
    /// new membership alone — recomputing it yields the identical split
    /// and the previous membership never enters, which is what lets
    /// every survivor of an elastic reconfiguration agree independently.
    #[test]
    fn prop_membership_transition_split_is_total_balanced_pure() {
        let (train, nm) = setup(4);
        crate::util::proptest::forall(
            97,
            16,
            |r| {
                // two memberships: non-empty machine subsets (4-bit
                // masks) with per-machine widths — "before" and "after"
                let before = (1 + r.usize_below(15), 1 + r.usize_below(3));
                let after = (1 + r.usize_below(15), 1 + r.usize_below(3));
                (before, after)
            },
            |&((mask0, per0), (mask1, per1))| {
                let members = |mask: usize| -> Vec<u32> {
                    (0..4u32).filter(|m| mask >> m & 1 == 1).collect()
                };
                let (m0, m1) = (members(mask0), members(mask1));
                // the "before" split exists but must not influence the
                // "after" split in any way
                let _ = split_training_set_for(
                    train.clone(),
                    &nm,
                    &m0,
                    per0,
                );
                let a = split_training_set_for(
                    train.clone(),
                    &nm,
                    &m1,
                    per1,
                );
                if a.len() != m1.len() * per1 {
                    return Err(format!(
                        "wrong set count {} for {m1:?} x {per1}",
                        a.len()
                    ));
                }
                let total: usize = a.iter().map(|s| s.len()).sum();
                if total != train.len() {
                    return Err(format!(
                        "lost items: {total} != {}",
                        train.len()
                    ));
                }
                let max = a.iter().map(|s| s.len()).max().unwrap();
                let min = a.iter().map(|s| s.len()).min().unwrap();
                if max - min > 1 {
                    return Err(format!("unbalanced: {min}..{max}"));
                }
                // purity: the same membership recomputes identically
                let b = split_training_set_for(
                    train.clone(),
                    &nm,
                    &m1,
                    per1,
                );
                if a != b {
                    return Err("re-split is not pure".into());
                }
                Ok(())
            },
        );
    }

    /// Property: any (machines, per_machine) split is total and balanced.
    #[test]
    fn prop_split_total_and_balanced() {
        let (train, nm) = setup(4);
        crate::util::proptest::forall(
            51,
            12,
            |r| (1 + r.usize_below(4), 1 + r.usize_below(4)),
            |&(m, per)| {
                let m = m.min(nm.nparts());
                let sets =
                    split_training_set(train.clone(), &nm, m, per);
                if sets.len() != m * per {
                    return Err("wrong set count".into());
                }
                let total: usize = sets.iter().map(|s| s.len()).sum();
                if total != train.len() {
                    return Err(format!(
                        "lost items: {total} != {}",
                        train.len()
                    ));
                }
                let max = sets.iter().map(|s| s.len()).max().unwrap();
                let min = sets.iter().map(|s| s.len()).min().unwrap();
                if max - min > 1 {
                    return Err(format!("unbalanced: {min}..{max}"));
                }
                Ok(())
            },
        );
    }
}
