//! Elastic-membership training driver (docs/DESIGN.md §9).
//!
//! Runs synchronous data-parallel SGD in *rounds*: within a round the
//! membership is frozen and every rank executes the classic step loop
//! (batch → device → ring all-reduce → momentum); at each epoch
//! boundary every surviving rank rendezvouses at the
//! [`Coordinator`] barrier, which decides `Continue` or
//! `Reconfigure(new view)` from the health signals accumulated during
//! the epoch (heartbeats, failure reports, planned resizes).
//!
//! On `Reconfigure` the round ends as a clean cut:
//!
//! 1. **drain** — every rank drops its loader, joining the sampling
//!    workers;
//! 2. **checkpoint** — the driver captures params + momentum velocity +
//!    the new membership record (rank state is synchronized at the
//!    boundary, so one copy is exact for everyone);
//! 3. **re-split** — [`Cluster::train_sets_for`] recomputes every
//!    survivor's training share as a pure function of the new
//!    membership, and loaders + the all-reduce group are rebuilt for
//!    the new world size, resuming the batch stream at the boundary's
//!    global step;
//! 4. **warmup** — the next round's first batch refills the pipeline.
//!
//! Determinism contract (test-enforced): because the re-split is pure
//! and per-rank loader seeds depend only on the logical rank, a run
//! that shrinks at boundary E streams byte-identical batches per rank —
//! and lands on byte-identical parameters — as a fresh deployment of
//! the smaller world resumed from the boundary checkpoint.
//!
//! A rank that loses its feature/sampler servers mid-epoch
//! (unrecoverable [`RpcError`](crate::net::RpcError)) cannot simply
//! exit: the ring all-reduce would deadlock. It becomes a *zombie* —
//! reports the failure, drops its loader, and keeps joining the
//! collective with unchanged parameters (and the same post-all-reduce
//! momentum update, which is rank-identical) until the boundary, where
//! the coordinator demotes its machine.
//!
//! Heartbeats carry *compute-only* step time — measured **before** the
//! all-reduce. The collective synchronizes every rank to the slowest
//! one, so a heartbeat taken after it would show near-identical times
//! on every machine and mask the very stragglers it is meant to expose.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::api::{DistGraph, DistNodeDataLoader, Seeds};
use crate::cluster::Cluster;
use crate::coordinator::{
    Coordinator, CoordinatorConfig, Decision, MembershipView,
};
use crate::ft::Checkpoint;
use crate::metrics::Metrics;

use super::{
    apply_momentum, epoch_windows, AllReduceGroup, DeviceExecutor,
    EpochStats, TrainConfig, TrainReport,
};

/// One membership reconfiguration, with its cost decomposition — the
/// `BENCH_elastic.json` row and the `reconfigurations` entries in
/// [`TrainReport`].
#[derive(Clone, Debug)]
pub struct ReconfigStats {
    /// Cumulative epoch-boundary count at which the decision was made.
    pub boundary: u64,
    /// Global step of the clean cut (== the checkpoint's step).
    pub at_step: usize,
    pub from_world: usize,
    pub to_world: usize,
    /// Machines removed by failure or straggler demotion (empty for a
    /// planned resize).
    pub demoted_machines: Vec<u32>,
    /// Max over ranks of the pipeline-teardown time.
    pub drain_secs: f64,
    /// Reconfiguration checkpoint capture + write (0.0 when the run has
    /// no `checkpoint_dir`).
    pub checkpoint_secs: f64,
    /// Membership re-split + loader/all-reduce rebuild.
    pub resplit_secs: f64,
    /// Next round's time-to-first-batch (pipeline refill), max over
    /// ranks.
    pub warmup_secs: f64,
}

/// What one rank thread hands back at the end of a round.
struct RoundOut {
    /// Losses from the round's executed steps (shorter than the round
    /// for a zombie — it stops training but keeps synchronizing).
    losses: Vec<f32>,
    params: Vec<Vec<f32>>,
    velocity: Vec<Vec<f32>>,
    /// The barrier decision that ended the round (`Continue` = ran to
    /// the final step).
    decision: Decision,
    /// Global step after the round's last executed step.
    stopped_at: usize,
    drain_secs: f64,
    first_batch_secs: f64,
}

/// Elastic counterpart of [`super::train`] — entered through it
/// whenever [`TrainConfig::is_elastic`] holds.
pub fn train_elastic(
    cluster: &Cluster,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let metrics = Arc::new(Metrics::new());

    // Device executors for every deployed machine: a demoted machine's
    // executor idles, and a planned grow can re-occupy it.
    let mut devices = Vec::with_capacity(cluster.spec.n_machines);
    for _ in 0..cluster.spec.n_machines {
        devices.push(DeviceExecutor::spawn(
            cluster.artifacts.clone(),
            cfg.variant.clone(),
            Some(cluster.cost.clone()),
        )?);
    }
    let mut params = devices[0].initial_params()?;
    let spec = devices[0].spec()?;
    anyhow::ensure!(
        spec.model != crate::sampler::compact::ModelKind::Rgcn
            || spec.num_rels >= cluster.schema.n_etypes(),
        "variant {:?} compiled for {} relations but the deployed schema \
         declares {} etypes — use the matching artifact (e.g. \
         rgcn_nc_mag) or align the dataset with num_rels=<n>",
        spec.name,
        spec.num_rels,
        cluster.schema.n_etypes()
    );

    // Exact resume, same contract as the classic loop — which is what a
    // post-shrink "fresh" deployment runs, so the two must agree byte
    // for byte on everything restored here.
    let mut start_step = 0usize;
    let mut ft_recovery_secs = 0.0f64;
    let mut velocity: Vec<Vec<f32>> = Vec::new();
    if !cfg.resume_from.is_empty() {
        let t_rec = Instant::now();
        let ck = Checkpoint::load(Path::new(&cfg.resume_from))?;
        anyhow::ensure!(
            ck.seed == cfg.seed,
            "checkpoint {} was written by a run with seed {}, this run \
             uses {} — the replayed stream would differ",
            cfg.resume_from,
            ck.seed,
            cfg.seed
        );
        anyhow::ensure!(
            ck.momentum == cfg.momentum,
            "checkpoint {} was written with momentum {}, this run uses \
             {} — the resumed optimizer state would be inconsistent",
            cfg.resume_from,
            ck.momentum,
            cfg.momentum
        );
        ck.restore(&cluster.kv.servers)?;
        start_step = ck.step as usize;
        params = ck.params;
        velocity = ck.velocity;
        ft_recovery_secs = t_rec.elapsed().as_secs_f64();
    }

    let co = Coordinator::new(
        MembershipView::initial(
            cluster.spec.n_machines,
            cluster.spec.trainers_per_machine,
        ),
        CoordinatorConfig {
            heartbeat_timeout: cfg.heartbeat_timeout,
            straggler_factor: cfg.straggler_factor,
            straggler_patience: cfg.straggler_patience,
            demote_stragglers: cfg.demote_stragglers,
            planned: cfg.elastic.clone(),
        },
    );
    let graph = DistGraph::new(cluster);
    let plan = cluster.fault_plan();

    let mut merged: Vec<f32> = Vec::new();
    let mut reconfigs: Vec<ReconfigStats> = Vec::new();
    let mut pending: Option<ReconfigStats> = None;
    let mut total_steps = cfg.max_steps;
    let mut spe0 = 0usize;
    let mut start = start_step;
    let cost0 = cluster.cost.snapshot();
    let t0 = Instant::now();

    loop {
        let view = co.view();
        let world = view.world_size();

        // re-split + rebuild: a pure function of the membership view,
        // so every round (and any fresh deployment of the same world)
        // computes identical shares
        let t_resplit = Instant::now();
        let sets =
            cluster.train_sets_for(&view.machines, view.per_machine);
        let mut loaders = Vec::with_capacity(world);
        for r in 0..world {
            loaders.push(
                DistNodeDataLoader::builder(&graph, &spec)
                    .machine(view.machine_of(r))
                    .seeds(Seeds::Nodes(sets[r].clone()))
                    .drop_last(cfg.drop_last)
                    .seed(cfg.seed ^ (r as u64) << 17)
                    .start_at(start as u64)
                    .pipeline(cfg.pipeline.clone())
                    .metrics(metrics.clone())
                    .build()?,
            );
        }
        let spe = loaders[0].len().max(1);
        let ar =
            AllReduceGroup::new(view.machine_vec(), cluster.cost.clone());
        if let Some(p) = pending.as_mut() {
            p.resplit_secs = t_resplit.elapsed().as_secs_f64();
        }
        if total_steps == 0 {
            total_steps = cfg.epochs * spe;
        }
        if spe0 == 0 {
            spe0 = spe;
            anyhow::ensure!(
                start_step < total_steps,
                "resume step {start_step} is not before the run's last \
                 step {total_steps} — nothing left to train"
            );
        }

        let mut handles = Vec::with_capacity(world);
        for (r, loader) in loaders.into_iter().enumerate() {
            let machine = view.machine_of(r);
            let device = devices[machine as usize].handle();
            let ep = ar.endpoint(r)?;
            let co = co.clone();
            let plan = plan.clone();
            let metrics = metrics.clone();
            let mut params = params.clone();
            let mut velocity = velocity.clone();
            let lr = cfg.lr;
            let momentum = cfg.momentum;
            let round_start = start;
            // rank 0 keeps the classic cadence checkpoints; elastic
            // runs stamp the current membership into them as well
            let write_ckpt = r == 0
                && cfg.checkpoint_every > 0
                && !cfg.checkpoint_dir.is_empty();
            let ckpt_every = cfg.checkpoint_every.max(1);
            let ckpt_dir = cfg.checkpoint_dir.clone();
            let ckpt_keep = cfg.checkpoint_keep;
            let ckpt_seed = cfg.seed;
            let ck_view = view.clone();
            let servers = cluster.kv.servers.clone();
            handles.push(std::thread::spawn(
                move || -> Result<RoundOut> {
                    let mut loader = Some(loader);
                    let mut losses = Vec::new();
                    let mut prev: Vec<Vec<f32>> = Vec::new();
                    let mut drain_secs = 0.0f64;
                    let mut first_batch_secs = 0.0f64;
                    let mut decision = Decision::Continue;
                    let mut stopped_at = total_steps;
                    for step in round_start..total_steps {
                        let t_step = Instant::now();
                        if let Some(ld) = loader.as_mut() {
                            let fetched =
                                metrics.time("trainer.wait_batch", || {
                                    ld.try_next_batch()
                                });
                            match fetched {
                                Ok(batch) => {
                                    if step == round_start {
                                        first_batch_secs =
                                            t_step.elapsed().as_secs_f64();
                                    }
                                    metrics.inc(
                                        "trainer.remote_rows",
                                        batch.remote_rows as u64,
                                    );
                                    metrics.inc(
                                        "trainer.dropped_nbrs",
                                        batch.dropped_neighbors as u64,
                                    );
                                    if momentum > 0.0 {
                                        prev.clone_from(&params);
                                    }
                                    let (loss, spent) =
                                        metrics.time("trainer.device", || {
                                            device.train_reusing(
                                                &mut params,
                                                batch,
                                                lr,
                                            )
                                        })?;
                                    loader.as_ref().unwrap().recycle(spent);
                                    losses.push(loss);
                                }
                                Err(_) => {
                                    // zombie mode: the pipeline is
                                    // unrecoverable, but leaving the
                                    // ring would deadlock everyone —
                                    // report, drain, keep synchronizing
                                    // with unchanged params until the
                                    // boundary demotes this machine
                                    co.report_failure(r);
                                    let t_drain = Instant::now();
                                    drop(loader.take());
                                    drain_secs =
                                        t_drain.elapsed().as_secs_f64();
                                    if momentum > 0.0 {
                                        prev.clone_from(&params);
                                    }
                                }
                            }
                        } else if momentum > 0.0 {
                            // a zombie's "gradient" is exactly zero:
                            // prev == params, so the momentum update
                            // below matches every live rank's
                            prev.clone_from(&params);
                        }
                        // injected asymmetric compute slowdown (the
                        // straggler the coordinator is meant to catch)
                        if let Some(p) = plan.as_ref() {
                            let d = p.step_delay(machine);
                            if !d.is_zero() {
                                std::thread::sleep(d);
                            }
                        }
                        // compute-only step time, taken BEFORE the
                        // all-reduce (which would equalize all ranks)
                        let compute_secs = t_step.elapsed().as_secs_f64();
                        metrics.time("trainer.allreduce", || {
                            ep.allreduce_params(&mut params)
                        })?;
                        if momentum > 0.0 {
                            apply_momentum(
                                &mut params,
                                &prev,
                                &mut velocity,
                                momentum,
                                lr,
                            );
                        }
                        if write_ckpt && (step + 1) % ckpt_every == 0 {
                            let at = (step + 1) as u64;
                            let ck = Checkpoint::capture(
                                ckpt_seed, at, &params, &servers,
                            )
                            .with_optimizer(momentum, velocity.clone())
                            .with_membership(ck_view.clone());
                            let bytes = ck.save(&Checkpoint::path_for(
                                Path::new(&ckpt_dir),
                                at,
                            ))?;
                            Checkpoint::prune(
                                Path::new(&ckpt_dir),
                                ckpt_keep,
                            )?;
                            metrics.inc("ft.checkpoints", 1);
                            metrics.inc("ft.checkpoint_bytes", bytes);
                        }
                        co.heartbeat(r, compute_secs);
                        // epoch boundary (global step axis): rendezvous
                        // for the membership decision — no barrier
                        // after the run's final step
                        if (step + 1) % spe == 0 && step + 1 < total_steps
                        {
                            if let Decision::Reconfigure(v) = co.barrier(r)
                            {
                                decision = Decision::Reconfigure(v);
                                stopped_at = step + 1;
                                break;
                            }
                        }
                    }
                    // drain: tear down the sampling pipeline before the
                    // re-split (a zombie already did)
                    if loader.is_some() {
                        let t_drain = Instant::now();
                        drop(loader.take());
                        drain_secs = t_drain.elapsed().as_secs_f64();
                    }
                    Ok(RoundOut {
                        losses,
                        params,
                        velocity,
                        decision,
                        stopped_at,
                        drain_secs,
                        first_batch_secs,
                    })
                },
            ));
        }

        let mut outs: Vec<RoundOut> = Vec::with_capacity(world);
        for h in handles {
            outs.push(h.join().expect("trainer thread panicked")?);
        }

        // the previous reconfiguration's warmup is this round's
        // time-to-first-batch
        if let Some(mut p) = pending.take() {
            p.warmup_secs = outs
                .iter()
                .map(|o| o.first_batch_secs)
                .fold(0.0, f64::max);
            reconfigs.push(p);
        }

        // merge this round's per-rank curves into the global one:
        // per-step mean over the ranks that actually trained the step
        // (zombies stop contributing after their failure)
        let round_steps = outs[0].stopped_at - start;
        for s in 0..round_steps {
            let vals: Vec<f32> = outs
                .iter()
                .filter_map(|o| o.losses.get(s).copied())
                .collect();
            merged.push(if vals.is_empty() {
                f32::NAN
            } else {
                vals.iter().sum::<f32>() / vals.len() as f32
            });
        }

        let drain_max =
            outs.iter().map(|o| o.drain_secs).fold(0.0, f64::max);
        let first = outs.swap_remove(0);
        params = first.params;
        velocity = first.velocity;

        match first.decision {
            Decision::Continue => break,
            Decision::Reconfigure(next) => {
                let stopped_at = first.stopped_at;
                // reconfiguration checkpoint: synchronized params +
                // velocity + the membership record the run moves to
                let t_ck = Instant::now();
                if !cfg.checkpoint_dir.is_empty() {
                    let ck = Checkpoint::capture(
                        cfg.seed,
                        stopped_at as u64,
                        &params,
                        &cluster.kv.servers,
                    )
                    .with_optimizer(cfg.momentum, velocity.clone())
                    .with_membership(next.clone());
                    let bytes = ck.save(&Checkpoint::path_for(
                        Path::new(&cfg.checkpoint_dir),
                        stopped_at as u64,
                    ))?;
                    Checkpoint::prune(
                        Path::new(&cfg.checkpoint_dir),
                        cfg.checkpoint_keep,
                    )?;
                    metrics.inc("ft.checkpoints", 1);
                    metrics.inc("ft.checkpoint_bytes", bytes);
                }
                let checkpoint_secs = t_ck.elapsed().as_secs_f64();
                metrics.inc("ft.reconfigurations", 1);
                let demoted: Vec<u32> = view
                    .machines
                    .iter()
                    .copied()
                    .filter(|m| !next.machines.contains(m))
                    .collect();
                pending = Some(ReconfigStats {
                    boundary: co.boundaries(),
                    at_step: stopped_at,
                    from_world: world,
                    to_world: next.world_size(),
                    demoted_machines: demoted,
                    drain_secs: drain_max,
                    checkpoint_secs,
                    resplit_secs: 0.0,
                    warmup_secs: 0.0,
                });
                start = stopped_at;
            }
        }
    }

    co.shutdown();
    metrics.inc("ft.demotions", co.demotions());
    if let Some(plan) = cluster.fault_plan() {
        plan.publish(&metrics);
    }
    if let Some(rs) = cluster.kv.replica_set() {
        rs.publish(&metrics);
    }
    let total_secs = t0.elapsed().as_secs_f64();
    let cost1 = cluster.cost.snapshot();
    let delta = cost0.delta(&cost1);
    let run_steps = total_steps - start_step;
    let loss_curve = merged;

    // epoch aggregation over the global step axis (first round's epoch
    // length — reconfigured rounds keep the original windowing so
    // elastic and classic reports line up)
    let mut epochs = Vec::new();
    let mut final_val_acc = None;
    for (e, (lo, hi)) in
        epoch_windows(spe0, total_steps).into_iter().enumerate()
    {
        let lo = lo.max(start_step);
        if lo >= hi {
            continue; // fully replayed by the checkpoint
        }
        let mean_loss = loss_curve[lo - start_step..hi - start_step]
            .iter()
            .map(|&x| x as f64)
            .sum::<f64>()
            / (hi - lo) as f64;
        epochs.push(EpochStats {
            epoch: e,
            mean_loss,
            secs: total_secs * (hi - lo) as f64 / run_steps as f64,
            val_acc: None,
        });
    }
    if cfg.eval_each_epoch {
        // evaluate on a surviving machine's executor (machine 0 may
        // have been demoted)
        let v = co.view();
        final_val_acc = Some(cluster.evaluate(
            &devices[v.machines[0] as usize].handle(),
            &spec,
            &params,
            cfg.seed,
        )?);
    }

    Ok(TrainReport::from_metrics(
        &metrics,
        epochs,
        total_secs,
        run_steps,
        loss_curve,
        delta.net_bytes,
        delta.pcie_bytes,
        final_val_acc,
        ft_recovery_secs,
        start_step as u64,
        params,
        reconfigs,
    ))
}
