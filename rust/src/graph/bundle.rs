//! Dataset bundle IO: persist a generated (or relabeled) dataset —
//! graph + features + labels + split — so partitioning/preprocessing is
//! paid once and reused across training runs (the paper's Table 2
//! workflow: ParMETIS output is saved and loaded by every job).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::generate::{Dataset, SplitTag};
use super::io::{read_f32_vec, write_f32_slice};
use super::schema::{EdgeTypeSpec, GraphSchema, NodeTypeSpec};
use super::Graph;

const MAGIC: u32 = 0xD157_B01D;
/// Bundle format version, tagged so it can never collide with the
/// name-length field that occupied this position in unversioned v1 files
/// (names are short; this value is not a plausible length). v2 appended
/// the [`GraphSchema`] section; v1 (pre-schema) files are rejected with a
/// descriptive error.
const VERSION: u32 = 0xDB00_0002;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u64(r)? as usize;
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

fn write_schema(w: &mut impl Write, s: &GraphSchema) -> Result<()> {
    write_u64(w, s.ntypes.len() as u64)?;
    for t in &s.ntypes {
        write_str(w, &t.name)?;
        write_u64(w, t.feat_dim as u64)?;
    }
    write_u64(w, s.etypes.len() as u64)?;
    for e in &s.etypes {
        write_str(w, &e.name)?;
        write_u64(w, e.fanout_weight as u64)?;
    }
    Ok(())
}

fn read_schema(r: &mut impl Read) -> Result<GraphSchema> {
    let nn = read_u64(r)? as usize;
    let mut ntypes = Vec::with_capacity(nn);
    for _ in 0..nn {
        let name = read_str(r)?;
        let feat_dim = read_u64(r)? as usize;
        ntypes.push(NodeTypeSpec { name, feat_dim });
    }
    let ne = read_u64(r)? as usize;
    let mut etypes = Vec::with_capacity(ne);
    for _ in 0..ne {
        let name = read_str(r)?;
        let fanout_weight = read_u64(r)? as usize;
        etypes.push(EdgeTypeSpec { name, fanout_weight });
    }
    let s = GraphSchema { ntypes, etypes };
    s.validate()?;
    Ok(s)
}

pub fn save_dataset(d: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    w.write_all(&MAGIC.to_le_bytes())?;
    write_u32(&mut w, VERSION)?;
    write_str(&mut w, &d.name)?;
    // graph (reuse the graph format inline)
    let tmp = path.with_extension("graph.tmp");
    super::io::save_graph(&d.graph, &tmp)?;
    let graph_bytes = std::fs::read(&tmp)?;
    std::fs::remove_file(&tmp).ok();
    write_u64(&mut w, graph_bytes.len() as u64)?;
    w.write_all(&graph_bytes)?;
    // features
    write_u64(&mut w, d.feat_dim as u64)?;
    write_f32_slice(&mut w, &d.feats)?;
    // labels + classes
    write_u64(&mut w, d.num_classes as u64)?;
    write_u64(&mut w, d.labels.len() as u64)?;
    for &l in &d.labels {
        w.write_all(&l.to_le_bytes())?;
    }
    // split tags
    write_u64(&mut w, d.split.len() as u64)?;
    for &s in &d.split {
        w.write_all(&[match s {
            SplitTag::Train => 1u8,
            SplitTag::Val => 2,
            SplitTag::Test => 3,
            SplitTag::None => 0,
        }])?;
    }
    // typed schema (trivial for homogeneous datasets)
    write_schema(&mut w, &d.schema)?;
    w.flush()?;
    Ok(())
}

pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut m = [0u8; 4];
    r.read_exact(&mut m)?;
    if u32::from_le_bytes(m) != MAGIC {
        bail!("bad magic in {path:?}");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!(
            "unsupported bundle version {version:#010x} in {path:?} \
             ({VERSION:#010x} expected; pre-schema bundles must be \
             regenerated)"
        );
    }
    let name = read_str(&mut r)?;
    let graph_len = read_u64(&mut r)? as usize;
    let mut graph_bytes = vec![0u8; graph_len];
    r.read_exact(&mut graph_bytes)?;
    let tmp = path.with_extension("graph.tmp");
    std::fs::write(&tmp, &graph_bytes)?;
    let graph: Graph = super::io::load_graph(&tmp)?;
    std::fs::remove_file(&tmp).ok();
    let feat_dim = read_u64(&mut r)? as usize;
    let feats = read_f32_vec(&mut r)?;
    let num_classes = read_u64(&mut r)? as usize;
    let n_labels = read_u64(&mut r)? as usize;
    let mut labels = vec![0u16; n_labels];
    let mut b2 = [0u8; 2];
    for l in labels.iter_mut() {
        r.read_exact(&mut b2)?;
        *l = u16::from_le_bytes(b2);
    }
    let n_split = read_u64(&mut r)? as usize;
    let mut split = Vec::with_capacity(n_split);
    let mut b1 = [0u8; 1];
    for _ in 0..n_split {
        r.read_exact(&mut b1)?;
        split.push(match b1[0] {
            1 => SplitTag::Train,
            2 => SplitTag::Val,
            3 => SplitTag::Test,
            0 => SplitTag::None,
            x => bail!("bad split tag {x}"),
        });
    }
    let schema = read_schema(&mut r)?;
    graph.validate_schema(&schema)?;
    Ok(Dataset {
        name,
        graph,
        schema,
        feats,
        feat_dim,
        labels,
        num_classes,
        split,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;

    #[test]
    fn dataset_roundtrip() {
        let d = DatasetSpec::new("rt", 800, 3200).generate();
        let dir = std::env::temp_dir().join("ddgl_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.bundle");
        save_dataset(&d, &p).unwrap();
        let d2 = load_dataset(&p).unwrap();
        assert_eq!(d.name, d2.name);
        assert_eq!(d.graph.targets, d2.graph.targets);
        assert_eq!(d.feats, d2.feats);
        assert_eq!(d.labels, d2.labels);
        assert_eq!(d.split, d2.split);
        assert_eq!(d.num_classes, d2.num_classes);
        assert_eq!(d.schema, d2.schema);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn typed_dataset_roundtrips_schema_and_types() {
        let d = DatasetSpec::paper_table1("mag-lsc", 100_000).generate();
        let dir = std::env::temp_dir().join("ddgl_bundle_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("mag.bundle");
        save_dataset(&d, &p).unwrap();
        let d2 = load_dataset(&p).unwrap();
        assert_eq!(d.schema, d2.schema);
        assert_eq!(d.graph.rel, d2.graph.rel);
        assert_eq!(d.graph.node_type, d2.graph.node_type);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("ddgl_bundle_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.bundle");
        std::fs::write(&p, b"garbage").unwrap();
        assert!(load_dataset(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
