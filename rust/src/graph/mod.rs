//! Graph storage: immutable CSR structure, builders, synthetic dataset
//! generators (the paper's OGB/Amazon workloads are reproduced as scaled
//! RMAT graphs — see docs/DESIGN.md §2), the typed [`GraphSchema`], and
//! binary partition IO.

pub mod builder;
pub mod bundle;
pub mod generate;
pub mod io;
pub mod schema;

pub use builder::GraphBuilder;
pub use generate::{Dataset, DatasetSpec, SplitTag};
pub use schema::{EdgeTypeSpec, FanoutPlan, GraphSchema, NodeTypeSpec};

/// Global node identifier (graphs up to 4B nodes).
pub type NodeId = u32;
/// Global edge identifier.
pub type EdgeId = u64;

/// Immutable CSR adjacency. Neighbors of `u` are
/// `targets[offsets[u]..offsets[u+1]]`. For GNN aggregation the stored
/// direction is *incoming* message edges (we symmetrize natural graphs at
/// build time, matching DGL's default for GraphSAGE).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub offsets: Vec<u64>,
    pub targets: Vec<NodeId>,
    /// Per-edge relation type (RGCN / heterogeneous graphs); empty = single
    /// relation.
    pub rel: Vec<u8>,
    /// Per-node type (heterogeneous graphs); empty = single node type.
    pub node_type: Vec<u8>,
}

impl Graph {
    pub fn n_nodes(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn n_edges(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, u: NodeId) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    #[inline]
    pub fn neighbors(&self, u: NodeId) -> &[NodeId] {
        &self.targets[self.offsets[u as usize] as usize
            ..self.offsets[u as usize + 1] as usize]
    }

    /// Relation types aligned with [`Self::neighbors`]; empty slice when the
    /// graph is homogeneous.
    #[inline]
    pub fn rel_of(&self, u: NodeId) -> &[u8] {
        if self.rel.is_empty() {
            &[]
        } else {
            &self.rel[self.offsets[u as usize] as usize
                ..self.offsets[u as usize + 1] as usize]
        }
    }

    /// Edge ids (positions in `targets`) of `u`'s adjacency.
    #[inline]
    pub fn edge_range(&self, u: NodeId) -> std::ops::Range<usize> {
        self.offsets[u as usize] as usize..self.offsets[u as usize + 1] as usize
    }

    pub fn node_type_of(&self, u: NodeId) -> u8 {
        if self.node_type.is_empty() {
            0
        } else {
            self.node_type[u as usize]
        }
    }

    /// Structural validation used by tests and after IO round-trips.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        ensure!(!self.offsets.is_empty(), "offsets empty");
        ensure!(self.offsets[0] == 0, "offsets must start at 0");
        ensure!(
            *self.offsets.last().unwrap() as usize == self.targets.len(),
            "offsets/targets mismatch"
        );
        for w in self.offsets.windows(2) {
            ensure!(w[0] <= w[1], "offsets not monotone");
        }
        let n = self.n_nodes() as NodeId;
        for &t in &self.targets {
            ensure!(t < n, "target {t} out of range {n}");
        }
        if !self.rel.is_empty() {
            ensure!(self.rel.len() == self.targets.len(), "rel len mismatch");
        }
        if !self.node_type.is_empty() {
            ensure!(
                self.node_type.len() == self.n_nodes(),
                "node_type len mismatch"
            );
        }
        Ok(())
    }

    /// [`Self::validate`] plus schema conformance: every `rel` value must
    /// name one of the schema's etypes and every `node_type` value one of
    /// its ntypes; a multi-etype (multi-ntype) schema additionally
    /// requires the per-edge (per-node) type array to be present.
    pub fn validate_schema(&self, schema: &GraphSchema) -> anyhow::Result<()> {
        use anyhow::ensure;
        self.validate()?;
        schema.validate()?;
        let ne = schema.n_etypes();
        let nn = schema.n_ntypes();
        if ne > 1 {
            ensure!(
                self.rel.len() == self.targets.len(),
                "schema has {ne} edge types but the graph carries no \
                 per-edge rel array"
            );
        }
        if let Some((i, &r)) = self
            .rel
            .iter()
            .enumerate()
            .find(|&(_, &r)| r as usize >= ne)
        {
            anyhow::bail!(
                "rel[{i}] = {r} out of range (schema has {ne} etypes)"
            );
        }
        if nn > 1 {
            ensure!(
                self.node_type.len() == self.n_nodes(),
                "schema has {nn} node types but the graph carries no \
                 per-node type array"
            );
        }
        if let Some((v, &t)) = self
            .node_type
            .iter()
            .enumerate()
            .find(|&(_, &t)| t as usize >= nn)
        {
            anyhow::bail!(
                "node_type[{v}] = {t} out of range (schema has {nn} ntypes)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line_graph(n: usize) -> Graph {
        // 0 - 1 - 2 - ... - (n-1), symmetric
        let mut b = GraphBuilder::new(n);
        for i in 0..n - 1 {
            b.add_edge(i as NodeId, (i + 1) as NodeId, 0);
            b.add_edge((i + 1) as NodeId, i as NodeId, 0);
        }
        b.build()
    }

    #[test]
    fn csr_accessors() {
        let g = line_graph(5);
        assert_eq!(g.n_nodes(), 5);
        assert_eq!(g.n_edges(), 8);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.neighbors(2), &[1, 3]);
        g.validate().unwrap();
    }

    #[test]
    fn empty_rel_is_homogeneous() {
        let g = line_graph(3);
        assert!(g.rel_of(1).is_empty());
        assert_eq!(g.node_type_of(1), 0);
    }

    #[test]
    fn validate_catches_bad_target() {
        let mut g = line_graph(3);
        g.targets[0] = 99;
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_schema_accepts_conforming_graphs() {
        // homogeneous graph + trivial schema
        let g = line_graph(4);
        g.validate_schema(&GraphSchema::homogeneous(8)).unwrap();
        // typed graph + matching 2-ntype / 2-etype schema
        let mut b = GraphBuilder::new(4);
        b.add_undirected(0, 1, 0);
        b.add_undirected(1, 2, 1);
        b.add_undirected(2, 3, 0);
        b.set_node_types(vec![0, 1, 0, 1]);
        let g = b.build();
        let schema = GraphSchema {
            ntypes: vec![
                NodeTypeSpec { name: "a".into(), feat_dim: 8 },
                NodeTypeSpec { name: "b".into(), feat_dim: 4 },
            ],
            etypes: vec![
                EdgeTypeSpec { name: "x".into(), fanout_weight: 1 },
                EdgeTypeSpec { name: "y".into(), fanout_weight: 1 },
            ],
        };
        g.validate_schema(&schema).unwrap();
    }

    #[test]
    fn validate_schema_rejects_out_of_range_rel() {
        let mut b = GraphBuilder::new(3);
        b.add_undirected(0, 1, 0);
        b.add_undirected(1, 2, 3); // rel 3 does not exist below
        let g = b.build();
        let err = g
            .validate_schema(&GraphSchema::homogeneous(8))
            .unwrap_err()
            .to_string();
        assert!(err.contains("rel["), "{err}");
    }

    #[test]
    fn validate_schema_rejects_out_of_range_node_type() {
        let mut b = GraphBuilder::new(3);
        b.add_undirected(0, 1, 0);
        b.set_node_types(vec![0, 7, 0]); // ntype 7 does not exist
        let g = b.build();
        let err = g
            .validate_schema(&GraphSchema::homogeneous(8))
            .unwrap_err()
            .to_string();
        assert!(err.contains("node_type["), "{err}");
    }

    #[test]
    fn validate_schema_requires_type_arrays_for_hetero_schemas() {
        // a 2-etype schema on a graph without a rel array must fail
        let g = line_graph(3); // no rel, no node_type
        let schema = GraphSchema {
            ntypes: vec![NodeTypeSpec { name: "n".into(), feat_dim: 4 }],
            etypes: vec![
                EdgeTypeSpec { name: "x".into(), fanout_weight: 1 },
                EdgeTypeSpec { name: "y".into(), fanout_weight: 1 },
            ],
        };
        assert!(g.validate_schema(&schema).is_err());
    }
}
