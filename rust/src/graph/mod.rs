//! Graph storage: immutable CSR structure, builders, synthetic dataset
//! generators (the paper's OGB/Amazon workloads are reproduced as scaled
//! RMAT graphs — see DESIGN.md §2), and binary partition IO.

pub mod builder;
pub mod bundle;
pub mod generate;
pub mod io;

pub use builder::GraphBuilder;
pub use generate::{Dataset, DatasetSpec, SplitTag};

/// Global node identifier (graphs up to 4B nodes).
pub type NodeId = u32;
/// Global edge identifier.
pub type EdgeId = u64;

/// Immutable CSR adjacency. Neighbors of `u` are
/// `targets[offsets[u]..offsets[u+1]]`. For GNN aggregation the stored
/// direction is *incoming* message edges (we symmetrize natural graphs at
/// build time, matching DGL's default for GraphSAGE).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub offsets: Vec<u64>,
    pub targets: Vec<NodeId>,
    /// Per-edge relation type (RGCN / heterogeneous graphs); empty = single
    /// relation.
    pub rel: Vec<u8>,
    /// Per-node type (heterogeneous graphs); empty = single node type.
    pub node_type: Vec<u8>,
}

impl Graph {
    pub fn n_nodes(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn n_edges(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, u: NodeId) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    #[inline]
    pub fn neighbors(&self, u: NodeId) -> &[NodeId] {
        &self.targets[self.offsets[u as usize] as usize
            ..self.offsets[u as usize + 1] as usize]
    }

    /// Relation types aligned with [`Self::neighbors`]; empty slice when the
    /// graph is homogeneous.
    #[inline]
    pub fn rel_of(&self, u: NodeId) -> &[u8] {
        if self.rel.is_empty() {
            &[]
        } else {
            &self.rel[self.offsets[u as usize] as usize
                ..self.offsets[u as usize + 1] as usize]
        }
    }

    /// Edge ids (positions in `targets`) of `u`'s adjacency.
    #[inline]
    pub fn edge_range(&self, u: NodeId) -> std::ops::Range<usize> {
        self.offsets[u as usize] as usize..self.offsets[u as usize + 1] as usize
    }

    pub fn node_type_of(&self, u: NodeId) -> u8 {
        if self.node_type.is_empty() {
            0
        } else {
            self.node_type[u as usize]
        }
    }

    /// Structural validation used by tests and after IO round-trips.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        ensure!(!self.offsets.is_empty(), "offsets empty");
        ensure!(self.offsets[0] == 0, "offsets must start at 0");
        ensure!(
            *self.offsets.last().unwrap() as usize == self.targets.len(),
            "offsets/targets mismatch"
        );
        for w in self.offsets.windows(2) {
            ensure!(w[0] <= w[1], "offsets not monotone");
        }
        let n = self.n_nodes() as NodeId;
        for &t in &self.targets {
            ensure!(t < n, "target {t} out of range {n}");
        }
        if !self.rel.is_empty() {
            ensure!(self.rel.len() == self.targets.len(), "rel len mismatch");
        }
        if !self.node_type.is_empty() {
            ensure!(
                self.node_type.len() == self.n_nodes(),
                "node_type len mismatch"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line_graph(n: usize) -> Graph {
        // 0 - 1 - 2 - ... - (n-1), symmetric
        let mut b = GraphBuilder::new(n);
        for i in 0..n - 1 {
            b.add_edge(i as NodeId, (i + 1) as NodeId, 0);
            b.add_edge((i + 1) as NodeId, i as NodeId, 0);
        }
        b.build()
    }

    #[test]
    fn csr_accessors() {
        let g = line_graph(5);
        assert_eq!(g.n_nodes(), 5);
        assert_eq!(g.n_edges(), 8);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.neighbors(2), &[1, 3]);
        g.validate().unwrap();
    }

    #[test]
    fn empty_rel_is_homogeneous() {
        let g = line_graph(3);
        assert!(g.rel_of(1).is_empty());
        assert_eq!(g.node_type_of(1), 0);
    }

    #[test]
    fn validate_catches_bad_target() {
        let mut g = line_graph(3);
        g.targets[0] = 99;
        assert!(g.validate().is_err());
    }
}
