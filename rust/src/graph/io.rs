//! Binary IO for graphs and partition bundles.
//!
//! Format (little-endian, versioned magic): used by `distdglv2 partition`
//! to persist partitions once and reuse them across training runs — the
//! paper's "partition once, train many times" workflow (§5.3, Table 2).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Graph;

const MAGIC: u32 = 0xD157_D617; // "DistDGl2"
const VERSION: u32 = 1;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_u64_slice(w: &mut impl Write, xs: &[u64]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_u32_slice(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_u8_slice(w: &mut impl Write, xs: &[u8]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    w.write_all(xs)?;
    Ok(())
}

pub fn write_f32_slice(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u64_vec(r: &mut impl Read) -> Result<Vec<u64>> {
    let n = read_u64(r)? as usize;
    let mut out = vec![0u64; n];
    let mut b = [0u8; 8];
    for x in out.iter_mut() {
        r.read_exact(&mut b)?;
        *x = u64::from_le_bytes(b);
    }
    Ok(out)
}

fn read_u32_vec(r: &mut impl Read) -> Result<Vec<u32>> {
    let n = read_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_u8_vec(r: &mut impl Read) -> Result<Vec<u8>> {
    let n = read_u64(r)? as usize;
    let mut out = vec![0u8; n];
    r.read_exact(&mut out)?;
    Ok(out)
}

pub fn read_f32_vec(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn save_graph(g: &Graph, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    write_u32(&mut w, MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u64_slice(&mut w, &g.offsets)?;
    write_u32_slice(&mut w, &g.targets)?;
    write_u8_slice(&mut w, &g.rel)?;
    write_u8_slice(&mut w, &g.node_type)?;
    w.flush()?;
    Ok(())
}

pub fn load_graph(path: &Path) -> Result<Graph> {
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    if read_u32(&mut r)? != MAGIC {
        bail!("bad magic in {path:?}");
    }
    let v = read_u32(&mut r)?;
    if v != VERSION {
        bail!("unsupported version {v}");
    }
    let g = Graph {
        offsets: read_u64_vec(&mut r)?,
        targets: read_u32_vec(&mut r)?,
        rel: read_u8_vec(&mut r)?,
        node_type: read_u8_vec(&mut r)?,
    };
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn graph_roundtrip() {
        let mut b = GraphBuilder::new(10);
        for i in 0..9u32 {
            b.add_undirected(i, i + 1, (i % 3) as u8);
        }
        let g = b.build();
        let dir = std::env::temp_dir().join("ddgl_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        save_graph(&g, &p).unwrap();
        let g2 = load_graph(&p).unwrap();
        assert_eq!(g.offsets, g2.offsets);
        assert_eq!(g.targets, g2.targets);
        assert_eq!(g.rel, g2.rel);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("ddgl_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.bin");
        std::fs::write(&p, b"not a graph").unwrap();
        assert!(load_graph(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
