//! Typed graph schema: the single description of a dataset's node and
//! edge types that every layer of the mini-batch path shares
//! (docs/DESIGN.md §6) — the generator derives `node_type`/`rel` arrays
//! from it, the partitioner balances per-ntype counts, the sampler splits
//! each layer's fanout across etypes, the KVStore keeps one feature table
//! per ntype, and the RGCN executable receives the sampled relation ids.
//!
//! Homogeneous graphs are **not** a separate code path: they use the
//! trivial schema ([`GraphSchema::homogeneous`], one ntype + one etype),
//! which degenerates every typed structure to its old untyped layout byte
//! for byte.

use anyhow::{ensure, Result};

/// One node type: display name + the feature width of its KVStore table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeTypeSpec {
    pub name: String,
    pub feat_dim: usize,
}

/// One edge type: display name + its relative share of each layer's
/// fanout budget (see [`FanoutPlan`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EdgeTypeSpec {
    pub name: String,
    pub fanout_weight: usize,
}

/// Node/edge type vocabulary of one dataset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GraphSchema {
    pub ntypes: Vec<NodeTypeSpec>,
    pub etypes: Vec<EdgeTypeSpec>,
}

impl GraphSchema {
    /// The trivial 1-ntype / 1-etype schema every homogeneous graph uses.
    pub fn homogeneous(feat_dim: usize) -> Self {
        Self {
            ntypes: vec![NodeTypeSpec {
                name: "node".to_string(),
                feat_dim,
            }],
            etypes: vec![EdgeTypeSpec {
                name: "edge".to_string(),
                fanout_weight: 1,
            }],
        }
    }

    pub fn n_ntypes(&self) -> usize {
        self.ntypes.len()
    }

    pub fn n_etypes(&self) -> usize {
        self.etypes.len()
    }

    pub fn is_homogeneous(&self) -> bool {
        self.ntypes.len() <= 1 && self.etypes.len() <= 1
    }

    /// Widest per-ntype feature dim (the padded row width of a batch).
    pub fn max_feat_dim(&self) -> usize {
        self.ntypes.iter().map(|t| t.feat_dim).max().unwrap_or(0)
    }

    /// Per-etype fanout weights (input to [`FanoutPlan::from_weights`]).
    pub fn fanout_weights(&self) -> Vec<usize> {
        self.etypes.iter().map(|e| e.fanout_weight).collect()
    }

    /// Structural validation (non-empty, positive dims, usable weights).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.ntypes.is_empty(), "schema has no node types");
        ensure!(!self.etypes.is_empty(), "schema has no edge types");
        for t in &self.ntypes {
            ensure!(t.feat_dim > 0, "ntype {:?} has feat_dim 0", t.name);
        }
        ensure!(
            self.etypes.iter().any(|e| e.fanout_weight > 0),
            "every etype has fanout weight 0"
        );
        Ok(())
    }
}

/// Split a per-layer fanout budget `k` across etypes proportionally to
/// `weights` (largest-remainder rounding; deterministic; the parts always
/// sum to exactly `k`). A single weight returns `[k]` — the homogeneous
/// case stays the plain uniform fanout.
///
/// When `k` covers the active (nonzero-weight) etypes, every one of them
/// is guaranteed ≥ 1 slot, so no relation is silently excluded from
/// sampling by rounding. Only when `k` is smaller than the number of
/// active etypes do the lowest-weighted ones get 0 — unavoidable, and
/// visible in the per-etype sampled-edge counters.
pub fn split_fanout(k: usize, weights: &[usize]) -> Vec<usize> {
    if weights.len() <= 1 {
        return vec![k];
    }
    let total: usize = weights.iter().sum();
    if total == 0 {
        // degenerate all-zero weights: fall back to an equal split so the
        // sum-to-k invariant holds
        return split_fanout(k, &vec![1usize; weights.len()]);
    }
    let nonzero = weights.iter().filter(|&&w| w > 0).count();
    if k >= nonzero {
        // floor of 1 per active etype, remainder split proportionally
        let mut parts: Vec<usize> =
            weights.iter().map(|&w| usize::from(w > 0)).collect();
        for (p, e) in parts
            .iter_mut()
            .zip(split_proportional(k - nonzero, weights))
        {
            *p += e;
        }
        return parts;
    }
    split_proportional(k, weights)
}

/// Largest-remainder proportional split (parts sum to exactly `k`;
/// ties break toward the lower index).
fn split_proportional(k: usize, weights: &[usize]) -> Vec<usize> {
    let total: usize = weights.iter().sum::<usize>().max(1);
    let mut parts: Vec<usize> = Vec::with_capacity(weights.len());
    let mut rems: Vec<(usize, usize)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (r, &w) in weights.iter().enumerate() {
        let exact = k * w;
        parts.push(exact / total);
        assigned += exact / total;
        rems.push((exact % total, r));
    }
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, r) in rems.iter().take(k - assigned) {
        parts[r] += 1;
    }
    parts
}

/// Per-layer, per-etype fanout plan: `layers[l-1][r]` is layer `l`'s
/// fanout for etype `r`; the per-layer sums equal the block's padded row
/// width `K_l`, so relation-aware sampling never overflows the compact
/// layout. A single-etype plan is exactly the classic uniform fanout
/// schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FanoutPlan {
    layers: Vec<Vec<usize>>,
}

impl FanoutPlan {
    /// Uniform plan (one etype): `fanouts[l-1]` = layer `l`'s K.
    pub fn uniform(fanouts: &[usize]) -> Self {
        Self {
            layers: fanouts.iter().map(|&k| vec![k]).collect(),
        }
    }

    /// Split every layer's K across etypes by explicit weights.
    pub fn from_weights(weights: &[usize], fanouts: &[usize]) -> Self {
        Self {
            layers: fanouts
                .iter()
                .map(|&k| split_fanout(k, weights))
                .collect(),
        }
    }

    /// Split every layer's K by the schema's etype fanout weights.
    pub fn from_schema(schema: &GraphSchema, fanouts: &[usize]) -> Self {
        Self::from_weights(&schema.fanout_weights(), fanouts)
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Per-etype fanouts of layer `l` (1-based, input side first).
    pub fn layer(&self, l: usize) -> &[usize] {
        &self.layers[l - 1]
    }

    /// Total fanout K of layer `l` (the padded row width).
    pub fn layer_total(&self, l: usize) -> usize {
        self.layers[l - 1].iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_schema_is_trivial() {
        let s = GraphSchema::homogeneous(32);
        assert!(s.is_homogeneous());
        assert_eq!(s.n_ntypes(), 1);
        assert_eq!(s.n_etypes(), 1);
        assert_eq!(s.max_feat_dim(), 32);
        s.validate().unwrap();
    }

    #[test]
    fn split_fanout_sums_to_k() {
        assert_eq!(split_fanout(5, &[1]), vec![5]);
        assert_eq!(split_fanout(5, &[1, 1, 1, 1]), vec![2, 1, 1, 1]);
        assert_eq!(split_fanout(8, &[3, 1]), vec![6, 2]);
        assert_eq!(split_fanout(2, &[1, 1, 1]), vec![1, 1, 0]);
        // all-zero weights degrade to an equal split, never to < k total
        assert_eq!(split_fanout(10, &[0, 0, 0, 0]), vec![3, 3, 2, 2]);
        // skewed weights cannot starve an active etype when k covers them
        assert_eq!(split_fanout(5, &[8, 1, 1, 1]), vec![2, 1, 1, 1]);
        assert!(split_fanout(6, &[100, 1, 1]).iter().all(|&p| p > 0));
        for (k, w) in [(7usize, vec![2usize, 5, 3]), (16, vec![1, 1]), (1, vec![9, 1, 1])] {
            let parts = split_fanout(k, &w);
            assert_eq!(parts.iter().sum::<usize>(), k, "k={k} w={w:?}");
            assert_eq!(parts.len(), w.len());
        }
    }

    #[test]
    fn split_fanout_is_deterministic_and_monotone_in_weight() {
        let a = split_fanout(10, &[4, 2, 1]);
        let b = split_fanout(10, &[4, 2, 1]);
        assert_eq!(a, b);
        assert!(a[0] >= a[1] && a[1] >= a[2], "{a:?}");
    }

    #[test]
    fn uniform_plan_matches_classic_fanouts() {
        let p = FanoutPlan::uniform(&[5, 10]);
        assert_eq!(p.num_layers(), 2);
        assert_eq!(p.layer(1), &[5]);
        assert_eq!(p.layer(2), &[10]);
        assert_eq!(p.layer_total(2), 10);
    }

    #[test]
    fn schema_plan_preserves_layer_totals() {
        let mut s = GraphSchema::homogeneous(8);
        s.etypes = vec![
            EdgeTypeSpec { name: "a".into(), fanout_weight: 2 },
            EdgeTypeSpec { name: "b".into(), fanout_weight: 1 },
            EdgeTypeSpec { name: "c".into(), fanout_weight: 1 },
        ];
        let p = FanoutPlan::from_schema(&s, &[5, 15]);
        assert_eq!(p.layer_total(1), 5);
        assert_eq!(p.layer_total(2), 15);
        assert_eq!(p.layer(1).len(), 3);
        assert!(p.layer(1)[0] >= p.layer(1)[1]);
    }

    #[test]
    fn invalid_schemas_rejected() {
        let mut s = GraphSchema::homogeneous(4);
        s.ntypes[0].feat_dim = 0;
        assert!(s.validate().is_err());
        let mut s2 = GraphSchema::homogeneous(4);
        s2.etypes.clear();
        assert!(s2.validate().is_err());
        let mut s3 = GraphSchema::homogeneous(4);
        s3.etypes[0].fanout_weight = 0;
        assert!(s3.validate().is_err());
    }
}
