//! Edge-list → CSR construction with sorting and optional dedup.

use super::{Graph, NodeId};

/// Accumulates (src, dst, rel) triples and builds an immutable CSR
/// [`Graph`]. Building is O(E log E) (sort by src, then dst).
pub struct GraphBuilder {
    n_nodes: usize,
    edges: Vec<(NodeId, NodeId, u8)>,
    node_type: Vec<u8>,
    has_rel: bool,
}

impl GraphBuilder {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            n_nodes,
            edges: Vec::new(),
            node_type: Vec::new(),
            has_rel: false,
        }
    }

    pub fn with_capacity(n_nodes: usize, n_edges: usize) -> Self {
        let mut b = Self::new(n_nodes);
        b.edges.reserve(n_edges);
        b
    }

    /// Add a directed edge dst-aggregates-from-src: stored under `dst`'s
    /// adjacency (incoming message edge).
    pub fn add_edge(&mut self, dst: NodeId, src: NodeId, rel: u8) {
        debug_assert!((dst as usize) < self.n_nodes);
        debug_assert!((src as usize) < self.n_nodes);
        if rel != 0 {
            self.has_rel = true;
        }
        self.edges.push((dst, src, rel));
    }

    /// Add both directions (symmetrization for natural graphs).
    pub fn add_undirected(&mut self, a: NodeId, b: NodeId, rel: u8) {
        self.add_edge(a, b, rel);
        self.add_edge(b, a, rel);
    }

    pub fn set_node_types(&mut self, types: Vec<u8>) {
        assert_eq!(types.len(), self.n_nodes);
        self.node_type = types;
    }

    /// Force a `rel` array in the built graph even when every edge is
    /// relation 0 (a multi-etype schema requires the array to exist).
    pub fn mark_relational(&mut self) {
        self.has_rel = true;
    }

    pub fn n_pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Build, removing duplicate (dst, src, rel) triples and self-loops.
    pub fn build_dedup(mut self) -> Graph {
        self.edges.retain(|&(d, s, _)| d != s);
        self.edges.sort_unstable();
        self.edges.dedup();
        self.finish()
    }

    /// Build keeping parallel edges (sorted for locality).
    pub fn build(mut self) -> Graph {
        self.edges.sort_unstable();
        self.finish()
    }

    fn finish(self) -> Graph {
        let mut offsets = vec![0u64; self.n_nodes + 1];
        for &(d, _, _) in &self.edges {
            offsets[d as usize + 1] += 1;
        }
        for i in 0..self.n_nodes {
            offsets[i + 1] += offsets[i];
        }
        let mut targets = Vec::with_capacity(self.edges.len());
        let mut rel = if self.has_rel {
            Vec::with_capacity(self.edges.len())
        } else {
            Vec::new()
        };
        for &(_, s, r) in &self.edges {
            targets.push(s);
            if self.has_rel {
                rel.push(r);
            }
        }
        Graph { offsets, targets, rel, node_type: self.node_type }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_sorts_and_counts() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(2, 1, 0);
        b.add_edge(0, 3, 0);
        b.add_edge(2, 0, 0);
        let g = b.build();
        assert_eq!(g.neighbors(0), &[3]);
        assert_eq!(g.neighbors(2), &[0, 1]);
        assert_eq!(g.degree(1), 0);
        g.validate().unwrap();
    }

    #[test]
    fn dedup_removes_dupes_and_selfloops() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 0);
        b.add_edge(0, 1, 0);
        b.add_edge(1, 1, 0); // self loop
        b.add_edge(2, 0, 0);
        let g = b.build_dedup();
        assert_eq!(g.n_edges(), 2);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.degree(1), 0);
    }

    #[test]
    fn rel_preserved_and_aligned() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 2);
        b.add_edge(0, 2, 1);
        b.add_edge(1, 0, 0);
        let g = b.build();
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.rel_of(0), &[2, 1]);
        assert_eq!(g.rel_of(1), &[0]);
    }

    #[test]
    fn undirected_adds_both() {
        let mut b = GraphBuilder::new(2);
        b.add_undirected(0, 1, 0);
        let g = b.build();
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0]);
    }
}
