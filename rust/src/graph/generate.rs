//! Synthetic dataset generation.
//!
//! The paper's testbed datasets (OGBN-PRODUCTS, AMAZON, OGBN-PAPERS100M,
//! MAG-LSC; Table 1) are not redistributable / not feasible at full scale on
//! this testbed, so we generate RMAT graphs with matching *structure*:
//! power-law degrees + recursive community structure (which drive partition
//! quality, sampling cost, and load imbalance — the properties the paper's
//! evaluation exercises), plus label-correlated features so accuracy curves
//! are meaningful. Scale factors are recorded with every result.

use super::schema::{EdgeTypeSpec, GraphSchema, NodeTypeSpec};
use super::{Graph, GraphBuilder, NodeId};
use crate::util::Rng;

/// Train/validation/test membership of a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitTag {
    Train,
    Val,
    Test,
    None,
}

/// A generated dataset: graph + schema + features + labels + split.
pub struct Dataset {
    pub name: String,
    pub graph: Graph,
    /// Node/edge type vocabulary; [`GraphSchema::homogeneous`] for plain
    /// graphs. Every downstream consumer (partitioner, sampler, KVStore,
    /// executable) keys off this.
    pub schema: GraphSchema,
    /// Row-major `[n_nodes, feat_dim]` (the generator's uniform source
    /// width; per-ntype KVStore tables slice the first `feat_dim(t)`
    /// columns of each row at registration).
    pub feats: Vec<f32>,
    pub feat_dim: usize,
    pub labels: Vec<u16>,
    pub num_classes: usize,
    pub split: Vec<SplitTag>,
}

impl Dataset {
    pub fn n_nodes(&self) -> usize {
        self.graph.n_nodes()
    }

    pub fn feature(&self, u: NodeId) -> &[f32] {
        let d = self.feat_dim;
        &self.feats[u as usize * d..(u as usize + 1) * d]
    }

    pub fn nodes_with(&self, tag: SplitTag) -> Vec<NodeId> {
        (0..self.n_nodes() as NodeId)
            .filter(|&u| self.split[u as usize] == tag)
            .collect()
    }
}

/// Generator parameters. `scale` divides the paper's node/edge counts.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub n_nodes: usize,
    pub n_edges: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Fraction of nodes labeled train/val/test.
    pub train_frac: f64,
    pub val_frac: f64,
    pub test_frac: f64,
    /// RMAT quadrant probabilities (a, b, c); d = 1-a-b-c. The defaults
    /// give power-law degrees with strong community structure.
    pub rmat: (f64, f64, f64),
    /// Number of edge relation types (RGCN); 1 = homogeneous.
    pub num_rels: usize,
    /// Heterogeneous node types as `(name, fraction-of-nodes, feat-dim
    /// divisor)`; empty = single node type. Types are assigned by
    /// contiguous id ranges (RMAT communities are id-blocks, so ranges
    /// stay type-coherent), and ntype `t`'s KVStore feature table is
    /// `feat_dim / divisor` wide.
    pub ntypes: Vec<(String, f64, usize)>,
    /// Edge type names (used when `num_rels > 1`); missing names are
    /// auto-generated as `rel<r>`.
    pub etype_names: Vec<String>,
    pub seed: u64,
}

impl DatasetSpec {
    pub fn new(name: &str, n_nodes: usize, n_edges: usize) -> Self {
        Self {
            name: name.to_string(),
            n_nodes,
            n_edges,
            feat_dim: 32,
            num_classes: 16,
            train_frac: 0.08,
            val_frac: 0.02,
            test_frac: 0.02,
            rmat: (0.57, 0.19, 0.19),
            num_rels: 1,
            ntypes: Vec::new(),
            etype_names: Vec::new(),
            seed: 42,
        }
    }

    /// Apply the MAG-style typed mix: paper/author/institution node types
    /// (fractions 0.50/0.42/0.08, feature-dim divisors 1/2/4) and 4
    /// endpoint-derived relations. The single source of the mix — the
    /// mag-lsc Table-1 arm and the hetero benches both use it, so they
    /// always measure the same typed shape.
    pub fn with_mag_types(mut self) -> Self {
        self.num_rels = 4;
        self.ntypes = vec![
            ("paper".to_string(), 0.50, 1),
            ("author".to_string(), 0.42, 2),
            ("institution".to_string(), 0.08, 4),
        ];
        self.etype_names = vec![
            "cites".to_string(),
            "writes".to_string(),
            "affiliated".to_string(),
            "interacts".to_string(),
        ];
        self
    }

    /// The [`GraphSchema`] this spec generates (derived from the current
    /// `feat_dim`/`num_rels`/`ntypes`, so overriding those fields after
    /// construction keeps the schema consistent).
    pub fn schema(&self) -> GraphSchema {
        let ntypes = if self.ntypes.is_empty() {
            vec![NodeTypeSpec {
                name: "node".to_string(),
                feat_dim: self.feat_dim,
            }]
        } else {
            self.ntypes
                .iter()
                .map(|(name, _, div)| NodeTypeSpec {
                    name: name.clone(),
                    feat_dim: (self.feat_dim / (*div).max(1)).max(1),
                })
                .collect()
        };
        let etypes = (0..self.num_rels.max(1))
            .map(|r| EdgeTypeSpec {
                name: self
                    .etype_names
                    .get(r)
                    .cloned()
                    .unwrap_or_else(|| format!("rel{r}")),
                fanout_weight: 1,
            })
            .collect();
        GraphSchema { ntypes, etypes }
    }

    /// Paper Table 1 dataset shapes, divided by `scale` (structure-preserving
    /// RMAT at reduced size). `scale=1000` fits this testbed comfortably.
    pub fn paper_table1(dataset: &str, scale: usize) -> Self {
        let s = scale.max(1);
        match dataset {
            // 2.4M nodes / 61.9M edges / 100 feats / 197K train
            "ogbn-products" => {
                let mut d = Self::new(
                    "ogbn-products",
                    (2_400_000 / s).max(1000),
                    (61_900_000 / s).max(4000),
                );
                d.feat_dim = 100;
                d.num_classes = 47;
                d.train_frac = 0.082;
                d
            }
            // 1.6M nodes / 264M edges / 200 feats (dense!)
            "amazon" => {
                let mut d = Self::new(
                    "amazon",
                    (1_600_000 / s).max(1000),
                    (264_000_000 / s).max(8000),
                );
                d.feat_dim = 200;
                d.num_classes = 107;
                d.train_frac = 0.8;
                d
            }
            // 111M nodes / 3.2B edges / 128 feats / 1.2M train (1%)
            "ogbn-papers100M" => {
                let mut d = Self::new(
                    "ogbn-papers100M",
                    (111_000_000 / s).max(2000),
                    (3_200_000_000usize / s).max(16_000),
                );
                d.feat_dim = 128;
                d.num_classes = 172;
                d.train_frac = 0.011;
                d
            }
            // 240M nodes / 7B edges / 756 feats, heterogeneous (RGCN):
            // paper/author/institution node types in MAG's rough
            // proportions; relations derive from endpoint types. Only
            // papers carry labels and the train/val/test split, and only
            // papers get full-width features (author/institution tables
            // are narrower, like MAG's featureless entity types).
            "mag-lsc" => {
                let mut d = Self::new(
                    "mag-lsc",
                    (240_000_000 / s).max(2000),
                    (7_000_000_000usize / s).max(16_000),
                )
                .with_mag_types();
                d.feat_dim = 136; // scaled from 756 to keep KVStore in RAM
                d.num_classes = 153;
                d.train_frac = 0.005;
                d
            }
            _ => panic!("unknown paper dataset {dataset}"),
        }
    }

    /// Generate the dataset (deterministic in `seed`).
    pub fn generate(&self) -> Dataset {
        if !self.ntypes.is_empty() && self.num_rels > 1 {
            // every declared etype must be reachable from some
            // endpoint-type pair (the MAG 3x4 shape has its own map)
            let t = self.ntypes.len();
            debug_assert!(
                self.num_rels <= t * (t + 1) / 2,
                "{} etypes but only {} endpoint-type pairs — some \
                 relations would never be generated",
                self.num_rels,
                t * (t + 1) / 2
            );
        }
        let mut rng = Rng::new(self.seed);
        let node_type = self.gen_node_types();
        let graph = self.gen_rmat(&node_type, &mut rng);
        let labels = self.gen_labels(&graph, &mut rng);
        let feats = self.gen_feats(&labels, &mut rng);
        let split = self.gen_split(&node_type, &mut rng);
        let schema = self.schema();
        debug_assert!(graph.validate_schema(&schema).is_ok());
        Dataset {
            name: self.name.clone(),
            graph,
            schema,
            feats,
            feat_dim: self.feat_dim,
            labels,
            num_classes: self.num_classes,
            split,
        }
    }

    /// Node types by contiguous id ranges following the spec fractions
    /// (empty for homogeneous specs). Ranges keep types community-aligned
    /// because RMAT communities are id-blocks.
    fn gen_node_types(&self) -> Vec<u8> {
        if self.ntypes.is_empty() {
            return Vec::new();
        }
        let total: f64 = self.ntypes.iter().map(|(_, f, _)| f).sum();
        let total = if total > 0.0 { total } else { 1.0 };
        let n = self.n_nodes;
        let mut out = vec![(self.ntypes.len() - 1) as u8; n];
        let mut start = 0usize;
        for (t, (_, frac, _)) in self.ntypes.iter().enumerate() {
            let len = ((frac / total) * n as f64).round() as usize;
            let end = (start + len).min(n);
            for v in out.iter_mut().take(end).skip(start) {
                *v = t as u8;
            }
            start = end;
        }
        out
    }

    /// Relation of a typed edge: a deterministic map from the (unordered)
    /// endpoint-type pair into `0..num_rels`. The MAG shape (3 ntypes,
    /// 4 etypes) gets its semantic map — paper–paper "cites",
    /// paper–author "writes", author–institution "affiliated", everything
    /// else the "interacts" catch-all. Other shapes spread pairs across
    /// all declared etypes by pair index, so no etype is unreachable
    /// as long as the pair count covers `num_rels` (debug-asserted at
    /// generation).
    fn rel_of_types(a: u8, b: u8, num_rels: usize, n_ntypes: usize) -> u8 {
        let nr = num_rels.max(1);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        if n_ntypes == 3 && nr == 4 {
            return match (lo, hi) {
                (0, 0) => 0,
                (0, 1) => 1,
                (1, 2) => 2,
                _ => 3,
            };
        }
        let pair = (hi as usize) * (hi as usize + 1) / 2 + lo as usize;
        (pair % nr) as u8
    }

    /// RMAT edge sampling: recursively descend a 2^k x 2^k adjacency matrix
    /// choosing quadrants with probabilities (a, b, c, d). Produces
    /// power-law degrees and hierarchical communities.
    fn gen_rmat(&self, node_type: &[u8], rng: &mut Rng) -> Graph {
        let levels = (self.n_nodes.max(2) as f64).log2().ceil() as u32;
        let side = 1usize << levels;
        let (a, b, c) = self.rmat;
        let mut builder =
            GraphBuilder::with_capacity(self.n_nodes, self.n_edges * 2);
        if self.num_rels > 1 {
            builder.mark_relational();
        }
        let mut added = 0usize;
        while added < self.n_edges {
            let (mut x, mut y) = (0usize, 0usize);
            let mut half = side >> 1;
            while half > 0 {
                let p = rng.f64();
                if p < a {
                    // top-left: nothing
                } else if p < a + b {
                    y += half;
                } else if p < a + b + c {
                    x += half;
                } else {
                    x += half;
                    y += half;
                }
                half >>= 1;
            }
            if x >= self.n_nodes || y >= self.n_nodes || x == y {
                continue;
            }
            let rel = if self.num_rels <= 1 {
                0
            } else if node_type.is_empty() {
                rng.below(self.num_rels as u64) as u8
            } else {
                Self::rel_of_types(
                    node_type[x],
                    node_type[y],
                    self.num_rels,
                    self.ntypes.len(),
                )
            };
            builder.add_undirected(x as NodeId, y as NodeId, rel);
            added += 1;
        }
        if !node_type.is_empty() {
            builder.set_node_types(node_type.to_vec());
        }
        builder.build_dedup()
    }

    /// Labels follow the RMAT community structure: the recursive quadrant
    /// construction makes id-space locality ≈ community membership, so
    /// nodes get the label of their id block, with a small random flip rate
    /// so the task is not trivial.
    fn gen_labels(&self, graph: &Graph, rng: &mut Rng) -> Vec<u16> {
        let n = self.n_nodes;
        let c = self.num_classes.max(1);
        let mut labels: Vec<u16> = (0..n)
            .map(|u| ((u * c) / n.max(1)) as u16)
            .collect();
        // 1 smoothing pass: adopt the majority label among neighbors; this
        // couples label to *structure* (not just id), like real communities.
        let snapshot = labels.clone();
        let mut hist = vec![0u32; c];
        for u in 0..n {
            let nbrs = graph.neighbors(u as NodeId);
            if nbrs.len() < 2 {
                continue;
            }
            for h in hist.iter_mut() {
                *h = 0;
            }
            for &v in nbrs {
                hist[snapshot[v as usize] as usize] += 1;
            }
            let (best, cnt) = hist
                .iter()
                .enumerate()
                .max_by_key(|(_, &c)| c)
                .map(|(i, &c)| (i, c))
                .unwrap();
            if cnt as usize * 2 > nbrs.len() {
                labels[u] = best as u16;
            }
        }
        // random flips (noise floor)
        for l in labels.iter_mut() {
            if rng.f64() < 0.05 {
                *l = rng.below(c as u64) as u16;
            }
        }
        labels
    }

    /// Features = class centroid + unit noise: linearly separable enough to
    /// learn, noisy enough that aggregation over neighbors helps (the GNN
    /// effect the paper's accuracy numbers rely on).
    fn gen_feats(&self, labels: &[u16], rng: &mut Rng) -> Vec<f32> {
        let d = self.feat_dim;
        let c = self.num_classes.max(1);
        // deterministic centroids
        let mut crng = Rng::new(self.seed ^ 0xC0FFEE);
        let centroids: Vec<f32> =
            (0..c * d).map(|_| crng.normal() as f32).collect();
        let mut feats = vec![0f32; labels.len() * d];
        for (u, &l) in labels.iter().enumerate() {
            let cen = &centroids[l as usize * d..(l as usize + 1) * d];
            for j in 0..d {
                feats[u * d + j] = 0.7 * cen[j] + (rng.normal() as f32);
            }
        }
        feats
    }

    /// Train/val/test assignment. Heterogeneous graphs restrict the split
    /// to ntype 0 (MAG: only papers are labeled); the RNG draw happens for
    /// every node so the stream — and thus every homogeneous dataset —
    /// is unchanged.
    fn gen_split(&self, node_type: &[u8], rng: &mut Rng) -> Vec<SplitTag> {
        (0..self.n_nodes)
            .map(|u| {
                let p = rng.f64();
                if !node_type.is_empty() && node_type[u] != 0 {
                    return SplitTag::None;
                }
                if p < self.train_frac {
                    SplitTag::Train
                } else if p < self.train_frac + self.val_frac {
                    SplitTag::Val
                } else if p < self.train_frac + self.val_frac + self.test_frac
                {
                    SplitTag::Test
                } else {
                    SplitTag::None
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Dataset {
        let mut spec = DatasetSpec::new("t", 2000, 8000);
        spec.seed = 7;
        spec.generate()
    }

    #[test]
    fn generates_valid_graph() {
        let d = small();
        d.graph.validate().unwrap();
        assert_eq!(d.n_nodes(), 2000);
        assert!(d.graph.n_edges() > 8000); // symmetrized, minus dedup
        assert_eq!(d.feats.len(), 2000 * d.feat_dim);
        assert_eq!(d.labels.len(), 2000);
        assert_eq!(d.split.len(), 2000);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = small();
        let b = small();
        assert_eq!(a.graph.targets, b.graph.targets);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.feats, b.feats);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        // RMAT should produce a heavy tail: max degree >> mean degree.
        let d = small();
        let degs: Vec<usize> =
            (0..d.n_nodes()).map(|u| d.graph.degree(u as NodeId)).collect();
        let mean = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        let max = *degs.iter().max().unwrap() as f64;
        assert!(max > 6.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn labels_correlate_with_neighbors() {
        // homophily: a neighbor shares the label far more often than chance
        let d = small();
        let mut same = 0usize;
        let mut total = 0usize;
        for u in 0..d.n_nodes() as NodeId {
            for &v in d.graph.neighbors(u) {
                total += 1;
                if d.labels[u as usize] == d.labels[v as usize] {
                    same += 1;
                }
            }
        }
        let frac = same as f64 / total.max(1) as f64;
        assert!(
            frac > 2.0 / d.num_classes as f64,
            "homophily too low: {frac}"
        );
    }

    #[test]
    fn split_fractions_roughly_match() {
        let d = small();
        let train = d.nodes_with(SplitTag::Train).len() as f64 / 2000.0;
        assert!((0.04..0.14).contains(&train), "train frac {train}");
    }

    #[test]
    fn paper_specs_have_expected_shape() {
        let s = DatasetSpec::paper_table1("ogbn-products", 1000);
        assert_eq!(s.feat_dim, 100);
        assert_eq!(s.num_classes, 47);
        let s = DatasetSpec::paper_table1("mag-lsc", 100_000);
        assert_eq!(s.num_rels, 4);
    }

    #[test]
    fn hetero_edges_get_relations() {
        let mut spec = DatasetSpec::new("h", 500, 2000);
        spec.num_rels = 4;
        let d = spec.generate();
        assert_eq!(d.graph.rel.len(), d.graph.n_edges());
        assert!(d.graph.rel.iter().any(|&r| r > 0));
        assert!(d.graph.rel.iter().all(|&r| r < 4));
        d.graph.validate_schema(&d.schema).unwrap();
    }

    #[test]
    fn homogeneous_dataset_gets_trivial_schema() {
        let d = small();
        assert!(d.schema.is_homogeneous());
        assert_eq!(d.schema.max_feat_dim(), d.feat_dim);
        assert!(d.graph.node_type.is_empty());
        assert!(d.graph.rel.is_empty());
    }

    #[test]
    fn mag_lsc_is_typed_end_to_end() {
        let spec = DatasetSpec::paper_table1("mag-lsc", 100_000);
        let d = spec.generate();
        let s = &d.schema;
        assert_eq!(s.n_ntypes(), 3);
        assert_eq!(s.n_etypes(), 4);
        assert_eq!(s.ntypes[0].name, "paper");
        assert_eq!(s.ntypes[0].feat_dim, spec.feat_dim);
        assert_eq!(s.ntypes[1].feat_dim, spec.feat_dim / 2);
        assert_eq!(s.ntypes[2].feat_dim, spec.feat_dim / 4);
        // typed arrays present, in range, schema-conforming
        assert_eq!(d.graph.node_type.len(), d.n_nodes());
        assert_eq!(d.graph.rel.len(), d.graph.n_edges());
        d.graph.validate_schema(s).unwrap();
        // all three node types and >= 2 relations actually occur
        let tset: std::collections::BTreeSet<u8> =
            d.graph.node_type.iter().copied().collect();
        assert_eq!(tset.len(), 3);
        let rset: std::collections::BTreeSet<u8> =
            d.graph.rel.iter().copied().collect();
        assert!(rset.len() >= 2, "{rset:?}");
    }

    #[test]
    fn typed_relations_are_endpoint_type_deterministic() {
        let spec = DatasetSpec::paper_table1("mag-lsc", 100_000);
        let d = spec.generate();
        let nt = &d.graph.node_type;
        for u in 0..d.n_nodes() as NodeId {
            let rels = d.graph.rel_of(u);
            for (i, &v) in d.graph.neighbors(u).iter().enumerate() {
                let expect = DatasetSpec::rel_of_types(
                    nt[u as usize],
                    nt[v as usize],
                    spec.num_rels,
                    spec.ntypes.len(),
                );
                assert_eq!(rels[i], expect, "edge ({u},{v})");
            }
        }
    }

    #[test]
    fn hetero_split_restricted_to_ntype0() {
        let spec = DatasetSpec::paper_table1("mag-lsc", 100_000);
        let d = spec.generate();
        for (u, &tag) in d.split.iter().enumerate() {
            if tag != SplitTag::None {
                assert_eq!(d.graph.node_type[u], 0, "labeled non-paper {u}");
            }
        }
        // a generous split over the same dataset shape must find papers
        let mut spec2 = DatasetSpec::paper_table1("mag-lsc", 100_000);
        spec2.train_frac = 0.5;
        let d2 = spec2.generate();
        assert!(!d2.nodes_with(SplitTag::Train).is_empty());
    }
}
