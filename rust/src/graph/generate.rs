//! Synthetic dataset generation.
//!
//! The paper's testbed datasets (OGBN-PRODUCTS, AMAZON, OGBN-PAPERS100M,
//! MAG-LSC; Table 1) are not redistributable / not feasible at full scale on
//! this testbed, so we generate RMAT graphs with matching *structure*:
//! power-law degrees + recursive community structure (which drive partition
//! quality, sampling cost, and load imbalance — the properties the paper's
//! evaluation exercises), plus label-correlated features so accuracy curves
//! are meaningful. Scale factors are recorded with every result.

use super::{Graph, GraphBuilder, NodeId};
use crate::util::Rng;

/// Train/validation/test membership of a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitTag {
    Train,
    Val,
    Test,
    None,
}

/// A generated dataset: graph + features + labels + split.
pub struct Dataset {
    pub name: String,
    pub graph: Graph,
    /// Row-major `[n_nodes, feat_dim]`.
    pub feats: Vec<f32>,
    pub feat_dim: usize,
    pub labels: Vec<u16>,
    pub num_classes: usize,
    pub split: Vec<SplitTag>,
}

impl Dataset {
    pub fn n_nodes(&self) -> usize {
        self.graph.n_nodes()
    }

    pub fn feature(&self, u: NodeId) -> &[f32] {
        let d = self.feat_dim;
        &self.feats[u as usize * d..(u as usize + 1) * d]
    }

    pub fn nodes_with(&self, tag: SplitTag) -> Vec<NodeId> {
        (0..self.n_nodes() as NodeId)
            .filter(|&u| self.split[u as usize] == tag)
            .collect()
    }
}

/// Generator parameters. `scale` divides the paper's node/edge counts.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub n_nodes: usize,
    pub n_edges: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Fraction of nodes labeled train/val/test.
    pub train_frac: f64,
    pub val_frac: f64,
    pub test_frac: f64,
    /// RMAT quadrant probabilities (a, b, c); d = 1-a-b-c. The defaults
    /// give power-law degrees with strong community structure.
    pub rmat: (f64, f64, f64),
    /// Number of edge relation types (RGCN); 1 = homogeneous.
    pub num_rels: usize,
    pub seed: u64,
}

impl DatasetSpec {
    pub fn new(name: &str, n_nodes: usize, n_edges: usize) -> Self {
        Self {
            name: name.to_string(),
            n_nodes,
            n_edges,
            feat_dim: 32,
            num_classes: 16,
            train_frac: 0.08,
            val_frac: 0.02,
            test_frac: 0.02,
            rmat: (0.57, 0.19, 0.19),
            num_rels: 1,
            seed: 42,
        }
    }

    /// Paper Table 1 dataset shapes, divided by `scale` (structure-preserving
    /// RMAT at reduced size). `scale=1000` fits this testbed comfortably.
    pub fn paper_table1(dataset: &str, scale: usize) -> Self {
        let s = scale.max(1);
        match dataset {
            // 2.4M nodes / 61.9M edges / 100 feats / 197K train
            "ogbn-products" => {
                let mut d = Self::new(
                    "ogbn-products",
                    (2_400_000 / s).max(1000),
                    (61_900_000 / s).max(4000),
                );
                d.feat_dim = 100;
                d.num_classes = 47;
                d.train_frac = 0.082;
                d
            }
            // 1.6M nodes / 264M edges / 200 feats (dense!)
            "amazon" => {
                let mut d = Self::new(
                    "amazon",
                    (1_600_000 / s).max(1000),
                    (264_000_000 / s).max(8000),
                );
                d.feat_dim = 200;
                d.num_classes = 107;
                d.train_frac = 0.8;
                d
            }
            // 111M nodes / 3.2B edges / 128 feats / 1.2M train (1%)
            "ogbn-papers100M" => {
                let mut d = Self::new(
                    "ogbn-papers100M",
                    (111_000_000 / s).max(2000),
                    (3_200_000_000usize / s).max(16_000),
                );
                d.feat_dim = 128;
                d.num_classes = 172;
                d.train_frac = 0.011;
                d
            }
            // 240M nodes / 7B edges / 756 feats, heterogeneous (RGCN)
            "mag-lsc" => {
                let mut d = Self::new(
                    "mag-lsc",
                    (240_000_000 / s).max(2000),
                    (7_000_000_000usize / s).max(16_000),
                );
                d.feat_dim = 136; // scaled from 756 to keep KVStore in RAM
                d.num_classes = 153;
                d.train_frac = 0.005;
                d.num_rels = 4;
                d
            }
            _ => panic!("unknown paper dataset {dataset}"),
        }
    }

    /// Generate the dataset (deterministic in `seed`).
    pub fn generate(&self) -> Dataset {
        let mut rng = Rng::new(self.seed);
        let graph = self.gen_rmat(&mut rng);
        let labels = self.gen_labels(&graph, &mut rng);
        let feats = self.gen_feats(&labels, &mut rng);
        let split = self.gen_split(&mut rng);
        Dataset {
            name: self.name.clone(),
            graph,
            feats,
            feat_dim: self.feat_dim,
            labels,
            num_classes: self.num_classes,
            split,
        }
    }

    /// RMAT edge sampling: recursively descend a 2^k x 2^k adjacency matrix
    /// choosing quadrants with probabilities (a, b, c, d). Produces
    /// power-law degrees and hierarchical communities.
    fn gen_rmat(&self, rng: &mut Rng) -> Graph {
        let levels = (self.n_nodes.max(2) as f64).log2().ceil() as u32;
        let side = 1usize << levels;
        let (a, b, c) = self.rmat;
        let mut builder =
            GraphBuilder::with_capacity(self.n_nodes, self.n_edges * 2);
        let mut added = 0usize;
        while added < self.n_edges {
            let (mut x, mut y) = (0usize, 0usize);
            let mut half = side >> 1;
            while half > 0 {
                let p = rng.f64();
                if p < a {
                    // top-left: nothing
                } else if p < a + b {
                    y += half;
                } else if p < a + b + c {
                    x += half;
                } else {
                    x += half;
                    y += half;
                }
                half >>= 1;
            }
            if x >= self.n_nodes || y >= self.n_nodes || x == y {
                continue;
            }
            let rel = if self.num_rels > 1 {
                rng.below(self.num_rels as u64) as u8
            } else {
                0
            };
            builder.add_undirected(x as NodeId, y as NodeId, rel);
            added += 1;
        }
        builder.build_dedup()
    }

    /// Labels follow the RMAT community structure: the recursive quadrant
    /// construction makes id-space locality ≈ community membership, so
    /// nodes get the label of their id block, with a small random flip rate
    /// so the task is not trivial.
    fn gen_labels(&self, graph: &Graph, rng: &mut Rng) -> Vec<u16> {
        let n = self.n_nodes;
        let c = self.num_classes.max(1);
        let mut labels: Vec<u16> = (0..n)
            .map(|u| ((u * c) / n.max(1)) as u16)
            .collect();
        // 1 smoothing pass: adopt the majority label among neighbors; this
        // couples label to *structure* (not just id), like real communities.
        let snapshot = labels.clone();
        let mut hist = vec![0u32; c];
        for u in 0..n {
            let nbrs = graph.neighbors(u as NodeId);
            if nbrs.len() < 2 {
                continue;
            }
            for h in hist.iter_mut() {
                *h = 0;
            }
            for &v in nbrs {
                hist[snapshot[v as usize] as usize] += 1;
            }
            let (best, cnt) = hist
                .iter()
                .enumerate()
                .max_by_key(|(_, &c)| c)
                .map(|(i, &c)| (i, c))
                .unwrap();
            if cnt as usize * 2 > nbrs.len() {
                labels[u] = best as u16;
            }
        }
        // random flips (noise floor)
        for l in labels.iter_mut() {
            if rng.f64() < 0.05 {
                *l = rng.below(c as u64) as u16;
            }
        }
        labels
    }

    /// Features = class centroid + unit noise: linearly separable enough to
    /// learn, noisy enough that aggregation over neighbors helps (the GNN
    /// effect the paper's accuracy numbers rely on).
    fn gen_feats(&self, labels: &[u16], rng: &mut Rng) -> Vec<f32> {
        let d = self.feat_dim;
        let c = self.num_classes.max(1);
        // deterministic centroids
        let mut crng = Rng::new(self.seed ^ 0xC0FFEE);
        let centroids: Vec<f32> =
            (0..c * d).map(|_| crng.normal() as f32).collect();
        let mut feats = vec![0f32; labels.len() * d];
        for (u, &l) in labels.iter().enumerate() {
            let cen = &centroids[l as usize * d..(l as usize + 1) * d];
            for j in 0..d {
                feats[u * d + j] = 0.7 * cen[j] + (rng.normal() as f32);
            }
        }
        feats
    }

    fn gen_split(&self, rng: &mut Rng) -> Vec<SplitTag> {
        (0..self.n_nodes)
            .map(|_| {
                let p = rng.f64();
                if p < self.train_frac {
                    SplitTag::Train
                } else if p < self.train_frac + self.val_frac {
                    SplitTag::Val
                } else if p < self.train_frac + self.val_frac + self.test_frac
                {
                    SplitTag::Test
                } else {
                    SplitTag::None
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Dataset {
        let mut spec = DatasetSpec::new("t", 2000, 8000);
        spec.seed = 7;
        spec.generate()
    }

    #[test]
    fn generates_valid_graph() {
        let d = small();
        d.graph.validate().unwrap();
        assert_eq!(d.n_nodes(), 2000);
        assert!(d.graph.n_edges() > 8000); // symmetrized, minus dedup
        assert_eq!(d.feats.len(), 2000 * d.feat_dim);
        assert_eq!(d.labels.len(), 2000);
        assert_eq!(d.split.len(), 2000);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = small();
        let b = small();
        assert_eq!(a.graph.targets, b.graph.targets);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.feats, b.feats);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        // RMAT should produce a heavy tail: max degree >> mean degree.
        let d = small();
        let degs: Vec<usize> =
            (0..d.n_nodes()).map(|u| d.graph.degree(u as NodeId)).collect();
        let mean = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        let max = *degs.iter().max().unwrap() as f64;
        assert!(max > 6.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn labels_correlate_with_neighbors() {
        // homophily: a neighbor shares the label far more often than chance
        let d = small();
        let mut same = 0usize;
        let mut total = 0usize;
        for u in 0..d.n_nodes() as NodeId {
            for &v in d.graph.neighbors(u) {
                total += 1;
                if d.labels[u as usize] == d.labels[v as usize] {
                    same += 1;
                }
            }
        }
        let frac = same as f64 / total.max(1) as f64;
        assert!(
            frac > 2.0 / d.num_classes as f64,
            "homophily too low: {frac}"
        );
    }

    #[test]
    fn split_fractions_roughly_match() {
        let d = small();
        let train = d.nodes_with(SplitTag::Train).len() as f64 / 2000.0;
        assert!((0.04..0.14).contains(&train), "train frac {train}");
    }

    #[test]
    fn paper_specs_have_expected_shape() {
        let s = DatasetSpec::paper_table1("ogbn-products", 1000);
        assert_eq!(s.feat_dim, 100);
        assert_eq!(s.num_classes, 47);
        let s = DatasetSpec::paper_table1("mag-lsc", 100_000);
        assert_eq!(s.num_rels, 4);
    }

    #[test]
    fn hetero_edges_get_relations() {
        let mut spec = DatasetSpec::new("h", 500, 2000);
        spec.num_rels = 4;
        let d = spec.generate();
        assert_eq!(d.graph.rel.len(), d.graph.n_edges());
        assert!(d.graph.rel.iter().any(|&r| r > 0));
        assert!(d.graph.rel.iter().all(|&r| r < 4));
    }
}
