//! Initial partitioning of the coarsest graph: greedy graph growing under
//! multi-constraint budgets (a BFS frontier absorbs vertices until the
//! part's primary budget fills, preferring vertices with many edges into
//! the growing region — the classic GGGP heuristic).

use super::{coarsen::WGraph, PartitionConfig};
use crate::util::Rng;

/// Greedily grow `nparts` regions; any remainder lands in the lightest part.
pub fn greedy_grow(wg: &WGraph, cfg: &PartitionConfig, rng: &mut Rng) -> Vec<u32> {
    let n = wg.n();
    let nparts = cfg.nparts;
    let ncon = wg.ncon;
    let mut totals = vec![0.0f32; ncon];
    for v in 0..n {
        for c in 0..ncon {
            totals[c] += wg.vwgt[v * ncon + c];
        }
    }
    let ideal: Vec<f32> =
        totals.iter().map(|t| t / nparts as f32).collect();

    let mut assign = vec![u32::MAX; n];
    let mut part_w = vec![vec![0.0f32; ncon]; nparts];

    for p in 0..nparts as u32 {
        // budget met when the primary constraint (vertex count) reaches ideal
        let mut frontier: Vec<u32> = Vec::new();
        // seed: random unassigned vertex
        let unassigned: Vec<u32> = (0..n as u32)
            .filter(|&v| assign[v as usize] == u32::MAX)
            .collect();
        if unassigned.is_empty() {
            break;
        }
        let seed = unassigned[rng.usize_below(unassigned.len())];
        frontier.push(seed);
        while part_w[p as usize][0] < ideal[0] {
            // pick the frontier vertex with max connectivity into p
            let v = match frontier.pop() {
                Some(v) => v,
                None => {
                    // region is disconnected from remaining graph: jump
                    match (0..n as u32)
                        .find(|&v| assign[v as usize] == u32::MAX)
                    {
                        Some(v) => v,
                        None => break,
                    }
                }
            };
            if assign[v as usize] != u32::MAX {
                continue;
            }
            assign[v as usize] = p;
            for c in 0..ncon {
                part_w[p as usize][c] += wg.vwgt[v as usize * ncon + c];
            }
            let (ts, _) = wg.nbrs(v);
            for &t in ts {
                if assign[t as usize] == u32::MAX {
                    frontier.push(t);
                }
            }
        }
    }

    // Remainder: lightest part by primary constraint.
    for v in 0..n {
        if assign[v] == u32::MAX {
            let p = (0..nparts)
                .min_by(|&a, &b| {
                    part_w[a][0].partial_cmp(&part_w[b][0]).unwrap()
                })
                .unwrap();
            assign[v] = p as u32;
            for c in 0..ncon {
                part_w[p][c] += wg.vwgt[v * ncon + c];
            }
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::VertexWeights;

    #[test]
    fn covers_all_vertices_within_balance() {
        let spec = DatasetSpec::new("i", 1000, 4000);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let wg = WGraph::from_graph(&d.graph, &vw);
        let cfg = PartitionConfig::new(4);
        let assign = greedy_grow(&wg, &cfg, &mut Rng::new(4));
        assert!(assign.iter().all(|&a| (a as usize) < 4));
        let mut counts = [0usize; 4];
        for &a in &assign {
            counts[a as usize] += 1;
        }
        for c in counts {
            assert!(c > 150, "unbalanced {counts:?}");
        }
    }
}
