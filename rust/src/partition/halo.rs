//! Physical partition construction (§5.3, Figure 6): every partition holds
//! its core vertices plus *all* incident edges, duplicating the remote
//! endpoints as HALO vertices. Samplers can then answer neighbor queries
//! for any local core vertex without cross-machine traffic — the
//! owner-compute rule's foundation.

use rustc_hash::FxHashMap;

use crate::graph::{Graph, NodeId};

use super::relabel::NodeMap;

/// One machine's physical partition, in *local* ID space:
/// locals `0..n_core` are core vertices (global `global_base + local`),
/// locals `n_core..` are halo duplicates (owned elsewhere).
#[derive(Clone, Debug)]
pub struct PhysPartition {
    pub part_id: u32,
    pub n_core: usize,
    /// Local CSR: full adjacency for cores, empty adjacency for halos.
    pub graph: Graph,
    /// local → (new) global id, for all locals.
    pub local_to_global: Vec<NodeId>,
    /// global → local for halo vertices only (cores are a subtraction).
    halo_index: FxHashMap<NodeId, u32>,
    pub global_base: u64,
}

impl PhysPartition {
    pub fn n_local(&self) -> usize {
        self.local_to_global.len()
    }

    pub fn n_halo(&self) -> usize {
        self.n_local() - self.n_core
    }

    #[inline]
    pub fn is_core_local(&self, local: u32) -> bool {
        (local as usize) < self.n_core
    }

    /// Map a (new) global id to a local id, if present in this partition.
    #[inline]
    pub fn local_of(&self, gid: NodeId) -> Option<u32> {
        let g = gid as u64;
        if g >= self.global_base && g < self.global_base + self.n_core as u64
        {
            Some((g - self.global_base) as u32)
        } else {
            self.halo_index.get(&gid).copied()
        }
    }

    #[inline]
    pub fn global_of(&self, local: u32) -> NodeId {
        self.local_to_global[local as usize]
    }

    /// Neighbors (as *global* ids) of a core vertex given by global id.
    pub fn neighbors_global<'a>(
        &'a self,
        gid: NodeId,
    ) -> impl Iterator<Item = NodeId> + 'a {
        let local = self
            .local_of(gid)
            .expect("neighbors_global: vertex not in partition");
        assert!(self.is_core_local(local), "halo vertices have no adjacency");
        self.graph
            .neighbors(local)
            .iter()
            .map(move |&l| self.local_to_global[l as usize])
    }
}

/// Build all physical partitions from the *relabeled* global graph.
pub fn build_partitions(g: &Graph, nm: &NodeMap) -> Vec<PhysPartition> {
    let nparts = nm.nparts();
    let mut out = Vec::with_capacity(nparts);
    for part in 0..nparts as u32 {
        out.push(build_one(g, nm, part));
    }
    out
}

fn build_one(g: &Graph, nm: &NodeMap, part: u32) -> PhysPartition {
    let range = nm.range(part);
    let n_core = (range.end - range.start) as usize;
    let base = range.start;

    // discover halo vertices (sorted for deterministic local ids)
    let mut halos: Vec<NodeId> = Vec::new();
    {
        let mut seen = FxHashMap::default();
        for c in 0..n_core {
            let gid = (base + c as u64) as NodeId;
            for &v in g.neighbors(gid) {
                let vg = v as u64;
                if !(vg >= range.start && vg < range.end)
                    && seen.insert(v, ()).is_none()
                {
                    halos.push(v);
                }
            }
        }
    }
    halos.sort_unstable();
    let mut halo_index = FxHashMap::default();
    for (i, &h) in halos.iter().enumerate() {
        halo_index.insert(h, (n_core + i) as u32);
    }

    let n_local = n_core + halos.len();
    let mut local_to_global = Vec::with_capacity(n_local);
    for c in 0..n_core {
        local_to_global.push((base + c as u64) as NodeId);
    }
    local_to_global.extend_from_slice(&halos);

    // local CSR: cores carry full adjacency, halos empty
    let has_rel = !g.rel.is_empty();
    let mut offsets = vec![0u64; n_local + 1];
    for c in 0..n_core {
        let gid = (base + c as u64) as NodeId;
        offsets[c + 1] = offsets[c] + g.degree(gid) as u64;
    }
    for h in n_core..n_local {
        offsets[h + 1] = offsets[h];
    }
    let n_local_edges = offsets[n_local] as usize;
    let mut targets = Vec::with_capacity(n_local_edges);
    let mut rel = if has_rel {
        Vec::with_capacity(n_local_edges)
    } else {
        Vec::new()
    };
    for c in 0..n_core {
        let gid = (base + c as u64) as NodeId;
        let rels = g.rel_of(gid);
        for (i, &v) in g.neighbors(gid).iter().enumerate() {
            let vg = v as u64;
            let local = if vg >= range.start && vg < range.end {
                (vg - base) as u32
            } else {
                halo_index[&v]
            };
            targets.push(local);
            if has_rel {
                rel.push(rels[i]);
            }
        }
    }

    let node_type = if g.node_type.is_empty() {
        Vec::new()
    } else {
        local_to_global
            .iter()
            .map(|&gid| g.node_type[gid as usize])
            .collect()
    };

    PhysPartition {
        part_id: part,
        n_core,
        graph: Graph { offsets, targets, rel, node_type },
        local_to_global,
        halo_index,
        global_base: base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{
        metis_partition, relabel, PartitionConfig, VertexWeights,
    };

    fn setup(
        n: usize,
        e: usize,
        k: usize,
    ) -> (Graph, NodeMap, Vec<PhysPartition>) {
        let spec = DatasetSpec::new("h", n, e);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(k));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let parts = build_partitions(&g, &r.node_map);
        (g, r.node_map, parts)
    }

    #[test]
    fn every_core_in_exactly_one_partition() {
        let (g, _, parts) = setup(900, 3600, 3);
        let total: usize = parts.iter().map(|p| p.n_core).sum();
        assert_eq!(total, g.n_nodes());
    }

    #[test]
    fn halo_closure_preserves_core_adjacency() {
        let (g, nm, parts) = setup(700, 2800, 4);
        for part in &parts {
            for c in 0..part.n_core as u32 {
                let gid = part.global_of(c);
                let mut expect: Vec<NodeId> = g.neighbors(gid).to_vec();
                expect.sort_unstable();
                let mut got: Vec<NodeId> =
                    part.neighbors_global(gid).collect();
                got.sort_unstable();
                assert_eq!(got, expect, "adjacency differs at {gid}");
            }
            // every halo is genuinely remote
            for h in part.n_core..part.n_local() {
                let gid = part.local_to_global[h];
                assert_ne!(nm.owner(gid), part.part_id);
            }
        }
    }

    #[test]
    fn halos_have_no_adjacency() {
        let (_, _, parts) = setup(500, 2000, 2);
        for part in &parts {
            for h in part.n_core..part.n_local() {
                assert_eq!(part.graph.degree(h as u32), 0);
            }
        }
    }

    #[test]
    fn local_of_roundtrips() {
        let (_, _, parts) = setup(600, 2400, 3);
        for part in &parts {
            for local in 0..part.n_local() as u32 {
                let gid = part.global_of(local);
                assert_eq!(part.local_of(gid), Some(local));
            }
            // a foreign non-halo id resolves to None
            assert_eq!(part.local_of(u32::MAX - 1), None);
        }
    }
}
