//! Second-level partitioning (§5.3): within each machine's partition, core
//! vertices are further split across the machine's GPUs/trainers. Only the
//! *training set assignment* uses this level (no feature duplication) — it
//! improves intra-batch locality so mini-batches touch fewer distinct
//! input vertices (Fig 14's "2-level partition" ablation bar).

use crate::graph::{Graph, NodeId};
use crate::util::Rng;

use super::{
    metis_partition, PartitionConfig, Partitioning, PhysPartition,
    VertexWeights,
};

/// Split one machine partition's cores into `nsub` buckets, balancing the
/// number of `train_mask`-set vertices per bucket while minimizing cut on
/// the induced core subgraph.
pub fn split_cores(
    part: &PhysPartition,
    train_mask: &[bool], // indexed by core-local id
    nsub: usize,
    seed: u64,
) -> Vec<u32> {
    assert_eq!(train_mask.len(), part.n_core);
    if nsub <= 1 {
        return vec![0; part.n_core];
    }
    // induced subgraph over cores (halo edges dropped)
    let mut offsets = vec![0u64; part.n_core + 1];
    let mut targets: Vec<NodeId> = Vec::new();
    for c in 0..part.n_core as u32 {
        for &t in part.graph.neighbors(c) {
            if (t as usize) < part.n_core {
                targets.push(t);
            }
        }
        offsets[c as usize + 1] = targets.len() as u64;
    }
    let induced = Graph {
        offsets,
        targets,
        rel: Vec::new(),
        node_type: Vec::new(),
    };

    // constraints: vertex count + train membership
    let mut w = vec![0.0f32; part.n_core * 2];
    for c in 0..part.n_core {
        w[c * 2] = 1.0;
        if train_mask[c] {
            w[c * 2 + 1] = 1.0;
        }
    }
    let vw = VertexWeights { ncon: 2, w };
    let mut cfg = PartitionConfig::new(nsub);
    cfg.seed = seed;
    cfg.coarsen_to = (nsub * 20).max(100);
    let p = metis_partition(&induced, &vw, &cfg);
    rebalance_train(p, train_mask, nsub, seed)
}

/// Post-pass: force train-vertex counts per bucket within ±1 of ideal by
/// moving surplus train vertices to deficit buckets (synchronous SGD needs
/// identical batch counts per trainer — §5.6.1).
fn rebalance_train(
    p: Partitioning,
    train_mask: &[bool],
    nsub: usize,
    seed: u64,
) -> Vec<u32> {
    let mut assign = p.assign;
    let train_ids: Vec<usize> = (0..assign.len())
        .filter(|&v| train_mask[v])
        .collect();
    let total = train_ids.len();
    let base = total / nsub;
    let mut extra = total % nsub; // first `extra` buckets get base+1
    let mut want: Vec<usize> = (0..nsub)
        .map(|_| {
            if extra > 0 {
                extra -= 1;
                base + 1
            } else {
                base
            }
        })
        .collect();
    let mut have = vec![0usize; nsub];
    for &v in &train_ids {
        have[assign[v] as usize] += 1;
    }
    // move from surplus to deficit (random order for fairness)
    let mut rng = Rng::new(seed ^ 0x5EED);
    let mut shuffled = train_ids;
    rng.shuffle(&mut shuffled);
    for &v in &shuffled {
        let cur = assign[v] as usize;
        if have[cur] > want[cur] {
            if let Some(tgt) = (0..nsub).find(|&b| have[b] < want[b]) {
                assign[v] = tgt as u32;
                have[cur] -= 1;
                have[tgt] += 1;
            }
        }
    }
    let _ = &mut want;
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{relabel, PartitionConfig};

    fn one_partition() -> (PhysPartition, Vec<bool>) {
        let spec = DatasetSpec::new("hier", 1000, 4000);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(2));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let d2 = relabel::relabel_dataset(&d, &r);
        let parts = super::super::halo::build_partitions(&g, &r.node_map);
        let part = parts.into_iter().next().unwrap();
        let mask: Vec<bool> = (0..part.n_core)
            .map(|c| {
                d2.split[part.global_of(c as u32) as usize]
                    == crate::graph::SplitTag::Train
            })
            .collect();
        (part, mask)
    }

    #[test]
    fn buckets_cover_cores_and_balance_train() {
        let (part, mask) = one_partition();
        let nsub = 4;
        let sub = split_cores(&part, &mask, nsub, 3);
        assert_eq!(sub.len(), part.n_core);
        assert!(sub.iter().all(|&s| (s as usize) < nsub));
        let mut train_counts = vec![0usize; nsub];
        for c in 0..part.n_core {
            if mask[c] {
                train_counts[sub[c] as usize] += 1;
            }
        }
        let max = *train_counts.iter().max().unwrap();
        let min = *train_counts.iter().min().unwrap();
        assert!(
            max - min <= 1,
            "train counts not balanced: {train_counts:?}"
        );
    }

    #[test]
    fn single_bucket_is_all_zero() {
        let (part, mask) = one_partition();
        let sub = split_cores(&part, &mask, 1, 3);
        assert!(sub.iter().all(|&s| s == 0));
    }
}
