//! Boundary refinement: greedy FM-style passes. A boundary vertex moves to
//! the neighboring part with the best gain (external − internal edge
//! weight) provided every balance constraint stays within
//! `eps × ideal`. Matches the paper's "single refinement iteration per
//! level" simplification for power-law graphs (§5.3.1), with the pass
//! count configurable.

use super::{coarsen::WGraph, PartitionConfig};
use crate::util::Rng;
use rustc_hash::FxHashMap;

pub fn refine(
    wg: &WGraph,
    assign: &mut [u32],
    cfg: &PartitionConfig,
    rng: &mut Rng,
) {
    let n = wg.n();
    let ncon = wg.ncon;
    let nparts = cfg.nparts;
    if nparts <= 1 {
        return;
    }

    let mut totals = vec![0.0f32; ncon];
    for v in 0..n {
        for c in 0..ncon {
            totals[c] += wg.vwgt[v * ncon + c];
        }
    }
    let ideal: Vec<f32> = totals.iter().map(|t| t / nparts as f32).collect();
    let cap: Vec<f32> = ideal
        .iter()
        .map(|i| {
            // constraints with tiny totals (e.g. few val nodes on a coarse
            // graph) get slack, otherwise nothing can move
            (i * cfg.eps).max(i + 2.0)
        })
        .collect();

    let mut part_w = vec![vec![0.0f32; ncon]; nparts];
    for v in 0..n {
        let p = assign[v] as usize;
        for c in 0..ncon {
            part_w[p][c] += wg.vwgt[v * ncon + c];
        }
    }

    let mut order: Vec<u32> = (0..n as u32).collect();
    for _pass in 0..cfg.refine_passes {
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        let mut conn: FxHashMap<u32, f32> = FxHashMap::default();
        for &v in &order {
            let vp = assign[v as usize];
            let (ts, ws) = wg.nbrs(v);
            if ts.is_empty() {
                continue;
            }
            conn.clear();
            for (&t, &w) in ts.iter().zip(ws) {
                *conn.entry(assign[t as usize]).or_insert(0.0) += w;
            }
            let internal = conn.get(&vp).copied().unwrap_or(0.0);
            // best candidate part by gain
            let mut best: Option<(u32, f32)> = None;
            for (&p, &w) in conn.iter() {
                if p == vp {
                    continue;
                }
                let gain = w - internal;
                if gain <= 0.0 {
                    continue;
                }
                if best.map_or(true, |(_, g)| gain > g) {
                    best = Some((p, gain));
                }
            }
            let Some((tp, _)) = best else { continue };
            // balance feasibility for every constraint
            let vw = wg.vw(v);
            let ok = (0..ncon).all(|c| {
                part_w[tp as usize][c] + vw[c] <= cap[c]
            }) && part_w[vp as usize][0] - vw[0] >= 1.0;
            if !ok {
                continue;
            }
            for c in 0..ncon {
                part_w[vp as usize][c] -= vw[c];
                part_w[tp as usize][c] += vw[c];
            }
            assign[v as usize] = tp;
            moved += 1;
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, NodeId};
    use crate::partition::VertexWeights;

    #[test]
    fn refine_fixes_bad_boundary() {
        // two cliques; start from a deliberately wrong assignment
        let k = 12usize;
        let mut b = GraphBuilder::new(2 * k);
        for a in 0..k {
            for c in (a + 1)..k {
                b.add_undirected(a as NodeId, c as NodeId, 0);
                b.add_undirected((k + a) as NodeId, (k + c) as NodeId, 0);
            }
        }
        b.add_undirected(0, k as NodeId, 0);
        let g = b.build_dedup();
        let vw = VertexWeights::uniform(g.n_nodes());
        let wg = WGraph::from_graph(&g, &vw);
        let mut cfg = PartitionConfig::new(2);
        cfg.refine_passes = 6;
        // wrong: swap 3 vertices across the cut
        let mut assign: Vec<u32> =
            (0..2 * k).map(|v| if v < k { 0 } else { 1 }).collect();
        assign[1] = 1;
        assign[2] = 1;
        assign[k + 1] = 0;
        assign[k + 2] = 0;
        refine(&wg, &mut assign, &mut cfg.clone(), &mut Rng::new(8));
        let cut = crate::partition::Partitioning { nparts: 2, assign }
            .edge_cut(&g);
        assert_eq!(cut, 1, "refinement failed to restore the clique split");
    }

    #[test]
    fn refine_preserves_partition_count() {
        let spec = crate::graph::DatasetSpec::new("r", 800, 3200);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let wg = WGraph::from_graph(&d.graph, &vw);
        let cfg = PartitionConfig::new(3);
        let mut assign: Vec<u32> =
            (0..800).map(|v| (v % 3) as u32).collect();
        refine(&wg, &mut assign, &cfg, &mut Rng::new(2));
        assert!(assign.iter().all(|&a| a < 3));
        let mut counts = [0usize; 3];
        for &a in &assign {
            counts[a as usize] += 1;
        }
        for c in counts {
            assert!(c > 0);
        }
    }
}
