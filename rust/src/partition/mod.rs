//! Hierarchical multi-constraint graph partitioning (paper §5.3).
//!
//! A from-scratch multilevel partitioner in the METIS family:
//!
//! 1. **Coarsening** ([`coarsen`]): heavy-edge matching, plus the paper's
//!    §5.3.1 power-law optimization — the coarse graph retains only the
//!    highest-weight edges so each coarse vertex's degree ≈ the average
//!    degree of its constituents (keeps coarse graphs sparse on power-law
//!    inputs).
//! 2. **Initial partitioning** ([`initial`]): greedy graph growing with
//!    multi-constraint budgets.
//! 3. **Refinement** ([`refine`]): boundary FM-style passes at every
//!    uncoarsening level, respecting all balance constraints.
//!
//! Multi-constraint balancing (§5.3.2): every vertex carries a weight
//! *vector* (node count, train/val/test membership, per-type counts) and
//! every constraint must stay within `(1 + eps) * ideal` per part — this is
//! what makes synchronous SGD iterations balanced across trainers.
//!
//! [`halo`] then materializes *physical* partitions (core + HALO vertices,
//! §5.3 Figure 6) and [`relabel`] renumbers global IDs so each partition's
//! core vertices form a contiguous range (owner lookup = binary search in a
//! `nparts`-sized array; global→local = one subtraction — §5.3). See
//! docs/DESIGN.md §3 for how this fits the whole system; typed graphs add
//! one balance constraint per node type (docs/DESIGN.md §6).

pub mod coarsen;
pub mod halo;
pub mod hierarchical;
pub mod initial;
pub mod random;
pub mod refine;
pub mod relabel;

use crate::graph::{Graph, NodeId};
use crate::util::Rng;

pub use halo::{build_partitions, PhysPartition};
pub use relabel::NodeMap;

/// Multi-constraint vertex weights: `w[v * ncon + c]`.
#[derive(Clone, Debug)]
pub struct VertexWeights {
    pub ncon: usize,
    pub w: Vec<f32>,
}

impl VertexWeights {
    /// Uniform single-constraint weights (plain balanced partitioning).
    pub fn uniform(n: usize) -> Self {
        Self { ncon: 1, w: vec![1.0; n] }
    }

    /// The paper's constraint set for training workloads: node count +
    /// train/val/test membership (+ one count per node type when
    /// heterogeneous).
    pub fn for_training(
        n: usize,
        split: &[crate::graph::SplitTag],
        node_type: &[u8],
        num_types: usize,
    ) -> Self {
        use crate::graph::SplitTag::*;
        let extra = if num_types > 1 { num_types } else { 0 };
        let ncon = 4 + extra;
        let mut w = vec![0.0f32; n * ncon];
        for v in 0..n {
            w[v * ncon] = 1.0;
            match split[v] {
                Train => w[v * ncon + 1] = 1.0,
                Val => w[v * ncon + 2] = 1.0,
                Test => w[v * ncon + 3] = 1.0,
                None => {}
            }
            if extra > 0 {
                let t = if node_type.is_empty() { 0 } else { node_type[v] };
                w[v * ncon + 4 + t as usize] = 1.0;
            }
        }
        Self { ncon, w }
    }

    #[inline]
    pub fn of(&self, v: usize) -> &[f32] {
        &self.w[v * self.ncon..(v + 1) * self.ncon]
    }

    pub fn totals(&self) -> Vec<f32> {
        let n = self.w.len() / self.ncon;
        let mut t = vec![0.0; self.ncon];
        for v in 0..n {
            for c in 0..self.ncon {
                t[c] += self.w[v * self.ncon + c];
            }
        }
        t
    }
}

/// Result of partitioning: `assign[v]` = part of vertex `v`.
#[derive(Clone, Debug)]
pub struct Partitioning {
    pub nparts: usize,
    pub assign: Vec<u32>,
}

impl Partitioning {
    /// Number of edges whose endpoints live in different parts.
    pub fn edge_cut(&self, g: &Graph) -> usize {
        let mut cut = 0usize;
        for u in 0..g.n_nodes() as NodeId {
            for &v in g.neighbors(u) {
                if self.assign[u as usize] != self.assign[v as usize] {
                    cut += 1;
                }
            }
        }
        cut / 2 // symmetric graphs store both directions
    }

    /// Per-part totals of each constraint.
    pub fn part_weights(&self, vw: &VertexWeights) -> Vec<Vec<f32>> {
        let mut pw = vec![vec![0.0f32; vw.ncon]; self.nparts];
        for (v, &p) in self.assign.iter().enumerate() {
            for c in 0..vw.ncon {
                pw[p as usize][c] += vw.w[v * vw.ncon + c];
            }
        }
        pw
    }

    /// Max over constraints of (max part weight / ideal part weight).
    pub fn imbalance(&self, vw: &VertexWeights) -> f32 {
        let pw = self.part_weights(vw);
        let totals = vw.totals();
        let mut worst = 0.0f32;
        for c in 0..vw.ncon {
            let ideal = totals[c] / self.nparts as f32;
            if ideal <= 0.0 {
                continue;
            }
            for p in &pw {
                worst = worst.max(p[c] / ideal);
            }
        }
        worst
    }
}

/// Tuning knobs for the multilevel algorithm.
#[derive(Clone, Debug)]
pub struct PartitionConfig {
    pub nparts: usize,
    /// Allowed imbalance per constraint (1.05 = 5%).
    pub eps: f32,
    /// Stop coarsening below this many vertices.
    pub coarsen_to: usize,
    /// Refinement passes per level (paper §5.3.1 runs a single refinement
    /// iteration for power-law graphs; we default to 2 for quality).
    pub refine_passes: usize,
    pub seed: u64,
    /// §5.3.1 degree-capped edge retention in coarse graphs.
    pub cap_coarse_degree: bool,
}

impl PartitionConfig {
    pub fn new(nparts: usize) -> Self {
        Self {
            nparts,
            eps: 1.10,
            coarsen_to: (nparts * 30).max(200),
            refine_passes: 2,
            seed: 1,
            cap_coarse_degree: true,
        }
    }
}

/// Multilevel multi-constraint partitioning (the paper's extended METIS).
pub fn metis_partition(
    g: &Graph,
    vw: &VertexWeights,
    cfg: &PartitionConfig,
) -> Partitioning {
    assert_eq!(vw.w.len(), g.n_nodes() * vw.ncon);
    if cfg.nparts <= 1 || g.n_nodes() == 0 {
        return Partitioning {
            nparts: cfg.nparts.max(1),
            assign: vec![0; g.n_nodes()],
        };
    }
    let mut rng = Rng::new(cfg.seed);
    let wg = coarsen::WGraph::from_graph(g, vw);
    let assign = multilevel(wg, cfg, &mut rng, 0);
    Partitioning { nparts: cfg.nparts, assign }
}

fn multilevel(
    wg: coarsen::WGraph,
    cfg: &PartitionConfig,
    rng: &mut Rng,
    depth: usize,
) -> Vec<u32> {
    // 64 levels would mean a pathological matching; bail to initial.
    if wg.n() <= cfg.coarsen_to || depth > 64 {
        let mut assign = initial::greedy_grow(&wg, cfg, rng);
        refine::refine(&wg, &mut assign, cfg, rng);
        return assign;
    }
    match coarsen::coarsen_once(&wg, cfg, rng) {
        Some((coarse, map)) => {
            let coarse_assign = multilevel(coarse, cfg, rng, depth + 1);
            // project back and refine at this level
            let mut assign: Vec<u32> =
                map.iter().map(|&c| coarse_assign[c as usize]).collect();
            refine::refine(&wg, &mut assign, cfg, rng);
            assign
        }
        Option::None => {
            let mut assign = initial::greedy_grow(&wg, cfg, rng);
            refine::refine(&wg, &mut assign, cfg, rng);
            assign
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DatasetSpec, GraphBuilder};

    /// Two dense cliques joined by one edge must split at the bridge.
    #[test]
    fn splits_two_cliques() {
        let k = 20usize;
        let mut b = GraphBuilder::new(2 * k);
        for a in 0..k {
            for c in (a + 1)..k {
                b.add_undirected(a as NodeId, c as NodeId, 0);
                b.add_undirected((k + a) as NodeId, (k + c) as NodeId, 0);
            }
        }
        b.add_undirected(0, k as NodeId, 0);
        let g = b.build_dedup();
        let vw = VertexWeights::uniform(g.n_nodes());
        let mut cfg = PartitionConfig::new(2);
        cfg.coarsen_to = 10;
        let p = metis_partition(&g, &vw, &cfg);
        assert_eq!(p.edge_cut(&g), 1, "assign={:?}", p.assign);
        assert!(p.imbalance(&vw) <= 1.01);
    }

    #[test]
    fn respects_multi_constraint_balance() {
        let spec = DatasetSpec::new("p", 3000, 12000);
        let d = spec.generate();
        let vw = VertexWeights::for_training(
            d.n_nodes(),
            &d.split,
            &d.graph.node_type,
            1,
        );
        let cfg = PartitionConfig::new(4);
        let p = metis_partition(&d.graph, &vw, &cfg);
        // node-count constraint must hold tightly; train constraint within eps
        let imb = p.imbalance(&vw);
        assert!(imb <= 1.35, "imbalance {imb}");
        // every part non-empty
        let pw = p.part_weights(&vw);
        for (i, w) in pw.iter().enumerate() {
            assert!(w[0] > 0.0, "part {i} empty");
        }
    }

    #[test]
    fn beats_random_on_edge_cut() {
        let spec = DatasetSpec::new("cut", 4000, 16000);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let cfg = PartitionConfig::new(4);
        let metis = metis_partition(&d.graph, &vw, &cfg);
        let rand = random::random_partition(d.n_nodes(), 4, 99);
        let mc = metis.edge_cut(&d.graph);
        let rc = rand.edge_cut(&d.graph);
        assert!(
            (mc as f64) < 0.7 * rc as f64,
            "metis cut {mc} vs random cut {rc}"
        );
    }

    #[test]
    fn single_part_is_identity() {
        let spec = DatasetSpec::new("one", 500, 1500);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(1));
        assert!(p.assign.iter().all(|&a| a == 0));
    }

    /// Property: assignment is always total and in-range.
    #[test]
    fn prop_assignment_total_and_in_range() {
        crate::util::proptest::forall(
            11,
            8,
            |r| {
                let n = 200 + r.usize_below(800);
                let e = n * (1 + r.usize_below(6));
                let k = 2 + r.usize_below(6);
                (n, e, k, r.next_u64())
            },
            |&(n, e, k, seed)| {
                let mut spec = DatasetSpec::new("pp", n, e);
                spec.seed = seed;
                let d = spec.generate();
                let vw = VertexWeights::uniform(d.n_nodes());
                let mut cfg = PartitionConfig::new(k);
                cfg.seed = seed;
                let p = metis_partition(&d.graph, &vw, &cfg);
                if p.assign.len() != n {
                    return Err(format!("len {} != {n}", p.assign.len()));
                }
                if let Some(&bad) =
                    p.assign.iter().find(|&&a| a as usize >= k)
                {
                    return Err(format!("part {bad} out of range {k}"));
                }
                Ok(())
            },
        );
    }
}
