//! Coarsening: heavy-edge matching + coarse-graph construction with the
//! paper's degree-capped edge retention (§5.3.1).
//!
//! On power-law graphs successive coarse graphs normally densify; the paper
//! extends METIS so each coarse vertex keeps only its highest-weight edges,
//! capped at the average degree of its constituent vertices, halving edges
//! roughly in step with vertices. `PartitionConfig::cap_coarse_degree`
//! toggles this (ablation: 5x memory / 8x time reduction claim).

use rustc_hash::FxHashMap;

use super::PartitionConfig;
use crate::graph::Graph;
use crate::util::Rng;

/// Weighted working graph for the multilevel hierarchy.
#[derive(Clone, Debug)]
pub struct WGraph {
    pub offsets: Vec<u64>,
    pub targets: Vec<u32>,
    pub ewgt: Vec<f32>,
    /// Multi-constraint vertex weights, `ncon` per vertex.
    pub ncon: usize,
    pub vwgt: Vec<f32>,
}

impl WGraph {
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn nbrs(&self, u: u32) -> (&[u32], &[f32]) {
        let r = self.offsets[u as usize] as usize
            ..self.offsets[u as usize + 1] as usize;
        (&self.targets[r.clone()], &self.ewgt[r])
    }

    pub fn vw(&self, u: u32) -> &[f32] {
        &self.vwgt[u as usize * self.ncon..(u as usize + 1) * self.ncon]
    }

    pub fn degree(&self, u: u32) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    pub fn from_graph(g: &Graph, vw: &super::VertexWeights) -> WGraph {
        WGraph {
            offsets: g.offsets.clone(),
            targets: g.targets.clone(),
            ewgt: vec![1.0; g.n_edges()],
            ncon: vw.ncon,
            vwgt: vw.w.clone(),
        }
    }
}

/// One coarsening step. Returns the coarse graph and the fine→coarse map,
/// or `None` if matching made no progress (graph can't shrink further).
pub fn coarsen_once(
    wg: &WGraph,
    cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Option<(WGraph, Vec<u32>)> {
    let n = wg.n();
    let matched = heavy_edge_matching(wg, rng);

    // Assign coarse ids: each matched pair and each unmatched vertex gets one.
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        map[v] = next;
        let m = matched[v];
        if m != u32::MAX && m as usize != v {
            map[m as usize] = next;
        }
        next += 1;
    }
    let cn = next as usize;
    if cn as f64 > 0.95 * n as f64 {
        return None; // no real progress; stop the hierarchy here
    }

    // Coarse vertex weights (sum constituents) + average constituent degree
    // for the §5.3.1 cap.
    let ncon = wg.ncon;
    let mut cvw = vec![0.0f32; cn * ncon];
    let mut members = vec![0u32; cn];
    let mut deg_sum = vec![0u64; cn];
    for v in 0..n {
        let c = map[v] as usize;
        for k in 0..ncon {
            cvw[c * ncon + k] += wg.vwgt[v * ncon + k];
        }
        members[c] += 1;
        deg_sum[c] += wg.degree(v as u32) as u64;
    }

    // Aggregate coarse adjacency.
    let mut adj: Vec<FxHashMap<u32, f32>> = vec![FxHashMap::default(); cn];
    for v in 0..n {
        let cv = map[v];
        let (ts, ws) = wg.nbrs(v as u32);
        for (&t, &w) in ts.iter().zip(ws) {
            let ct = map[t as usize];
            if ct != cv {
                *adj[cv as usize].entry(ct).or_insert(0.0) += w;
            }
        }
    }

    // §5.3.1: keep only the top-(avg constituent degree) edges per coarse
    // vertex; an edge survives if either endpoint retains it (symmetry).
    let mut keep: Vec<Vec<(u32, f32)>> = Vec::with_capacity(cn);
    for c in 0..cn {
        let mut es: Vec<(u32, f32)> =
            adj[c].iter().map(|(&t, &w)| (t, w)).collect();
        if cfg.cap_coarse_degree {
            let cap = ((deg_sum[c] as f64 / members[c].max(1) as f64).ceil()
                as usize)
                .max(2);
            if es.len() > cap {
                es.sort_unstable_by(|a, b| {
                    b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
                });
                es.truncate(cap);
            }
        }
        keep.push(es);
    }
    let mut retained: Vec<FxHashMap<u32, f32>> =
        vec![FxHashMap::default(); cn];
    for c in 0..cn as u32 {
        for &(t, w) in &keep[c as usize] {
            retained[c as usize].entry(t).or_insert(w);
            retained[t as usize].entry(c).or_insert(w);
        }
    }

    // Materialize CSR.
    let mut offsets = vec![0u64; cn + 1];
    for c in 0..cn {
        offsets[c + 1] = offsets[c] + retained[c].len() as u64;
    }
    let mut targets = Vec::with_capacity(offsets[cn] as usize);
    let mut ewgt = Vec::with_capacity(offsets[cn] as usize);
    for r in retained.iter() {
        let mut es: Vec<(u32, f32)> = r.iter().map(|(&t, &w)| (t, w)).collect();
        es.sort_unstable_by_key(|e| e.0);
        for (t, w) in es {
            targets.push(t);
            ewgt.push(w);
        }
    }

    Some((
        WGraph { offsets, targets, ewgt, ncon, vwgt: cvw },
        map,
    ))
}

/// Randomized heavy-edge matching: visit vertices in random order, match
/// each unmatched vertex to its unmatched neighbor with the heaviest edge.
fn heavy_edge_matching(wg: &WGraph, rng: &mut Rng) -> Vec<u32> {
    let n = wg.n();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut matched = vec![u32::MAX; n];
    for &v in &order {
        if matched[v as usize] != u32::MAX {
            continue;
        }
        let (ts, ws) = wg.nbrs(v);
        let mut best: Option<(u32, f32)> = None;
        for (&t, &w) in ts.iter().zip(ws) {
            if t != v && matched[t as usize] == u32::MAX {
                if best.map_or(true, |(_, bw)| w > bw) {
                    best = Some((t, w));
                }
            }
        }
        if let Some((t, _)) = best {
            matched[v as usize] = t;
            matched[t as usize] = v;
        } else {
            matched[v as usize] = v; // matched with itself (singleton)
        }
    }
    matched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::VertexWeights;

    fn wgraph(n: usize, e: usize, seed: u64) -> WGraph {
        let mut spec = DatasetSpec::new("c", n, e);
        spec.seed = seed;
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        WGraph::from_graph(&d.graph, &vw)
    }

    #[test]
    fn coarsen_shrinks_and_preserves_weight() {
        let wg = wgraph(2000, 8000, 3);
        let cfg = PartitionConfig::new(2);
        let mut rng = Rng::new(5);
        let (coarse, map) = coarsen_once(&wg, &cfg, &mut rng).unwrap();
        assert!(coarse.n() < wg.n());
        assert!(coarse.n() >= wg.n() / 2);
        // total vertex weight is conserved
        let orig: f32 = wg.vwgt.iter().sum();
        let c: f32 = coarse.vwgt.iter().sum();
        assert!((orig - c).abs() < 1e-3);
        // map is total and in range
        assert_eq!(map.len(), wg.n());
        assert!(map.iter().all(|&m| (m as usize) < coarse.n()));
    }

    #[test]
    fn matching_is_symmetric() {
        let wg = wgraph(1000, 4000, 9);
        let mut rng = Rng::new(2);
        let m = heavy_edge_matching(&wg, &mut rng);
        for v in 0..wg.n() {
            let mv = m[v];
            assert_ne!(mv, u32::MAX);
            if mv as usize != v {
                assert_eq!(m[mv as usize], v as u32, "asymmetric at {v}");
            }
        }
    }

    #[test]
    fn degree_cap_reduces_edges() {
        let wg = wgraph(3000, 24000, 7);
        let mut c_on = PartitionConfig::new(2);
        c_on.cap_coarse_degree = true;
        let mut c_off = c_on.clone();
        c_off.cap_coarse_degree = false;
        let (g_on, _) =
            coarsen_once(&wg, &c_on, &mut Rng::new(1)).unwrap();
        let (g_off, _) =
            coarsen_once(&wg, &c_off, &mut Rng::new(1)).unwrap();
        assert!(
            g_on.targets.len() <= g_off.targets.len(),
            "cap should not add edges"
        );
    }

    #[test]
    fn coarse_graph_is_valid_symmetric_csr() {
        let wg = wgraph(1500, 6000, 11);
        let cfg = PartitionConfig::new(2);
        let (c, _) = coarsen_once(&wg, &cfg, &mut Rng::new(3)).unwrap();
        // offsets monotone, targets in range, adjacency symmetric
        for w in c.offsets.windows(2) {
            assert!(w[0] <= w[1]);
        }
        for v in 0..c.n() as u32 {
            let (ts, _) = c.nbrs(v);
            for &t in ts {
                assert!((t as usize) < c.n());
                let (back, _) = c.nbrs(t);
                assert!(back.contains(&v), "edge {v}->{t} not symmetric");
            }
        }
    }
}
