//! Global ID relabeling (§5.3): after partitioning, vertex IDs are permuted
//! so every partition's core vertices occupy a contiguous range. Owner
//! lookup then is a binary search in an `nparts+1` array and global→local
//! conversion is a subtraction — the paper's trick for cheap ID mapping.

use crate::graph::{Dataset, Graph, GraphBuilder, NodeId};

use super::Partitioning;

/// Partition ownership expressed as contiguous new-ID ranges.
#[derive(Clone, Debug)]
pub struct NodeMap {
    pub part_starts: Vec<u64>, // len nparts+1
}

impl NodeMap {
    pub fn nparts(&self) -> usize {
        self.part_starts.len() - 1
    }

    /// Owning partition of a (new) global id — binary search (§5.3).
    #[inline]
    pub fn owner(&self, gid: NodeId) -> u32 {
        let g = gid as u64;
        // partition_point returns the first index with start > g
        (self.part_starts.partition_point(|&s| s <= g) - 1) as u32
    }

    /// Core-local offset of a (new) global id within its partition.
    #[inline]
    pub fn local_of(&self, gid: NodeId) -> u32 {
        let p = self.owner(gid);
        (gid as u64 - self.part_starts[p as usize]) as u32
    }

    #[inline]
    pub fn global_of(&self, part: u32, local: u32) -> NodeId {
        (self.part_starts[part as usize] + local as u64) as NodeId
    }

    pub fn n_core(&self, part: u32) -> usize {
        (self.part_starts[part as usize + 1]
            - self.part_starts[part as usize]) as usize
    }

    pub fn range(&self, part: u32) -> std::ops::Range<u64> {
        self.part_starts[part as usize]..self.part_starts[part as usize + 1]
    }
}

/// The permutation produced by relabeling.
#[derive(Clone, Debug)]
pub struct Relabeling {
    pub node_map: NodeMap,
    pub old_to_new: Vec<NodeId>,
    pub new_to_old: Vec<NodeId>,
}

/// Compute the relabeling: new ids ordered by (partition, old id).
pub fn relabel(p: &Partitioning) -> Relabeling {
    let n = p.assign.len();
    let mut counts = vec![0u64; p.nparts + 1];
    for &a in &p.assign {
        counts[a as usize + 1] += 1;
    }
    for i in 0..p.nparts {
        counts[i + 1] += counts[i];
    }
    let part_starts = counts.clone();
    let mut cursor = counts;
    let mut old_to_new = vec![0 as NodeId; n];
    let mut new_to_old = vec![0 as NodeId; n];
    for old in 0..n {
        let part = p.assign[old] as usize;
        let new = cursor[part];
        cursor[part] += 1;
        old_to_new[old] = new as NodeId;
        new_to_old[new as usize] = old as NodeId;
    }
    Relabeling {
        node_map: NodeMap { part_starts },
        old_to_new,
        new_to_old,
    }
}

/// Rebuild a graph under the permutation (adjacency preserved).
pub fn relabel_graph(g: &Graph, r: &Relabeling) -> Graph {
    let n = g.n_nodes();
    let mut b = GraphBuilder::with_capacity(n, g.n_edges());
    if !g.rel.is_empty() {
        b.mark_relational(); // keep the rel array even if all-zero
    }
    for u in 0..n as NodeId {
        let nu = r.old_to_new[u as usize];
        let rels = g.rel_of(u);
        for (i, &v) in g.neighbors(u).iter().enumerate() {
            let rel = if rels.is_empty() { 0 } else { rels[i] };
            b.add_edge(nu, r.old_to_new[v as usize], rel);
        }
    }
    if !g.node_type.is_empty() {
        let mut nt = vec![0u8; n];
        for old in 0..n {
            nt[r.old_to_new[old] as usize] = g.node_type[old];
        }
        b.set_node_types(nt);
    }
    b.build()
}

/// Permute a whole dataset (features, labels, split) to the new ID space.
pub fn relabel_dataset(d: &Dataset, r: &Relabeling) -> Dataset {
    let n = d.n_nodes();
    let dim = d.feat_dim;
    let mut feats = vec![0f32; d.feats.len()];
    let mut labels = vec![0u16; n];
    let mut split = vec![crate::graph::SplitTag::None; n];
    for old in 0..n {
        let new = r.old_to_new[old] as usize;
        feats[new * dim..(new + 1) * dim]
            .copy_from_slice(&d.feats[old * dim..(old + 1) * dim]);
        labels[new] = d.labels[old];
        split[new] = d.split[old];
    }
    Dataset {
        name: d.name.clone(),
        graph: relabel_graph(&d.graph, r),
        schema: d.schema.clone(),
        feats,
        feat_dim: dim,
        labels,
        num_classes: d.num_classes,
        split,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{metis_partition, PartitionConfig, VertexWeights};

    fn setup() -> (Dataset, Partitioning, Relabeling) {
        let spec = DatasetSpec::new("rl", 1200, 4800);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(4));
        let r = relabel(&p);
        (d, p, r)
    }

    #[test]
    fn permutation_is_bijection() {
        let (_, _, r) = setup();
        let n = r.old_to_new.len();
        for old in 0..n {
            assert_eq!(r.new_to_old[r.old_to_new[old] as usize], old as NodeId);
        }
    }

    #[test]
    fn cores_are_contiguous_and_owner_matches() {
        let (_, p, r) = setup();
        for old in 0..p.assign.len() {
            let new = r.old_to_new[old];
            assert_eq!(
                r.node_map.owner(new),
                p.assign[old],
                "owner mismatch for old={old}"
            );
        }
        // ranges partition the id space exactly
        assert_eq!(r.node_map.part_starts[0], 0);
        assert_eq!(
            *r.node_map.part_starts.last().unwrap() as usize,
            p.assign.len()
        );
    }

    #[test]
    fn local_global_roundtrip() {
        let (_, _, r) = setup();
        let nm = &r.node_map;
        for part in 0..nm.nparts() as u32 {
            for local in 0..nm.n_core(part) as u32 {
                let g = nm.global_of(part, local);
                assert_eq!(nm.owner(g), part);
                assert_eq!(nm.local_of(g), local);
            }
        }
    }

    #[test]
    fn relabeled_graph_preserves_adjacency() {
        let (d, _, r) = setup();
        let g2 = relabel_graph(&d.graph, &r);
        g2.validate().unwrap();
        assert_eq!(g2.n_edges(), d.graph.n_edges());
        for old_u in 0..d.n_nodes() as NodeId {
            let new_u = r.old_to_new[old_u as usize];
            let mut expect: Vec<NodeId> = d
                .graph
                .neighbors(old_u)
                .iter()
                .map(|&v| r.old_to_new[v as usize])
                .collect();
            expect.sort_unstable();
            let mut got = g2.neighbors(new_u).to_vec();
            got.sort_unstable();
            assert_eq!(got, expect, "adjacency mismatch at old={old_u}");
        }
    }

    #[test]
    fn relabeled_dataset_moves_features_with_nodes() {
        let (d, _, r) = setup();
        let d2 = relabel_dataset(&d, &r);
        for old in 0..d.n_nodes() {
            let new = r.old_to_new[old] as usize;
            assert_eq!(d.labels[old], d2.labels[new]);
            assert_eq!(d.split[old], d2.split[new]);
            assert_eq!(
                d.feature(old as NodeId),
                d2.feature(new as NodeId)
            );
        }
    }

    /// Property: owner() agrees with a linear scan for random maps.
    #[test]
    fn prop_owner_binary_search() {
        crate::util::proptest::forall(
            21,
            30,
            |rng| {
                let nparts = 1 + rng.usize_below(9);
                let mut starts = vec![0u64];
                for _ in 0..nparts {
                    let last = *starts.last().unwrap();
                    starts.push(last + 1 + rng.below(50));
                }
                (starts, rng.next_u64())
            },
            |(starts, seed)| {
                let nm = NodeMap { part_starts: starts.clone() };
                let n = *starts.last().unwrap();
                let mut rng = crate::util::Rng::new(*seed);
                for _ in 0..50 {
                    let g = rng.below(n) as NodeId;
                    let expect = (0..nm.nparts())
                        .find(|&p| {
                            (g as u64) >= starts[p]
                                && (g as u64) < starts[p + 1]
                        })
                        .unwrap() as u32;
                    if nm.owner(g) != expect {
                        return Err(format!(
                            "owner({g}) = {} != {expect}",
                            nm.owner(g)
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
