//! Random partitioning — the Euler baseline (§6.1: "Euler uses random
//! partitioning"), also used to quantify the METIS benefit in the Fig 14
//! ablation.

use super::Partitioning;
use crate::util::Rng;

pub fn random_partition(n: usize, nparts: usize, seed: u64) -> Partitioning {
    let mut rng = Rng::new(seed);
    Partitioning {
        nparts,
        assign: (0..n).map(|_| rng.below(nparts as u64) as u32).collect(),
    }
}

/// Round-robin striping (perfectly balanced, locality-free) — a second
/// baseline matching hash-partitioned industrial systems.
pub fn striped_partition(n: usize, nparts: usize) -> Partitioning {
    Partitioning {
        nparts,
        assign: (0..n).map(|v| (v % nparts) as u32).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_is_roughly_balanced() {
        let p = random_partition(10_000, 4, 1);
        let mut counts = [0usize; 4];
        for &a in &p.assign {
            counts[a as usize] += 1;
        }
        for c in counts {
            assert!((2_200..2_800).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn striped_is_exactly_balanced() {
        let p = striped_partition(1000, 8);
        let mut counts = [0usize; 8];
        for &a in &p.assign {
            counts[a as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 125));
    }
}
