//! Run configuration: dataset/cluster/training knobs with `key=value` CLI
//! parsing (offline environment: no clap; the grammar is deliberately
//! simple and fully covered by tests).

use anyhow::{bail, Context, Result};

use crate::cluster::{ClusterSpec, Partitioner};
use crate::graph::DatasetSpec;
use crate::kvstore::CacheAdmission;
use crate::pipeline::PipelineMode;
use crate::trainer::TrainConfig;

/// Everything one `distdglv2 train` invocation needs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetSpec,
    pub cluster: ClusterSpec,
    pub train: TrainConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetSpec::new("rmat-small", 20_000, 120_000),
            cluster: ClusterSpec::new(2, 2),
            train: TrainConfig::default(),
        }
    }
}

impl RunConfig {
    /// Apply one `key=value` override. Unknown keys error with the list of
    /// valid keys.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let parse_usize = || -> Result<usize> {
            value.parse().with_context(|| format!("{key}={value}"))
        };
        match key {
            "dataset" => {
                // named paper dataset at scale, or rmat:<nodes>:<edges>
                if let Some(rest) = value.strip_prefix("rmat:") {
                    let (n, e) = rest
                        .split_once(':')
                        .context("rmat:<nodes>:<edges>")?;
                    self.dataset = DatasetSpec::new(
                        &format!("rmat-{n}-{e}"),
                        n.parse()?,
                        e.parse()?,
                    );
                } else {
                    let (name, scale) =
                        value.split_once('@').unwrap_or((value, "1000"));
                    self.dataset = DatasetSpec::paper_table1(
                        name,
                        scale.parse()?,
                    );
                }
            }
            "feat_dim" => self.dataset.feat_dim = parse_usize()?,
            "classes" => self.dataset.num_classes = parse_usize()?,
            // align the dataset's relation count with a compiled RGCN
            // variant (e.g. `dataset=mag-lsc@1000 num_rels=3` to drive
            // the 3-relation rgcn_nc_dev artifact). Keys apply in CLI
            // order, so place it AFTER `dataset=` — the dataset arm
            // rebuilds the whole spec and would clobber an earlier
            // override.
            "num_rels" => self.dataset.num_rels = parse_usize()?,
            "dataset_seed" => self.dataset.seed = value.parse()?,
            "machines" => self.cluster.n_machines = parse_usize()?,
            "trainers" => self.cluster.trainers_per_machine = parse_usize()?,
            "partitioner" => {
                self.cluster.partitioner = match value {
                    "metis" => Partitioner::Metis,
                    "random" => Partitioner::Random,
                    _ => bail!("partitioner must be metis|random"),
                }
            }
            "multi_constraint" => {
                self.cluster.multi_constraint = parse_bool(value)?
            }
            "two_level" => self.cluster.two_level = parse_bool(value)?,
            "emulate_network" => {
                self.cluster.emulate_network_time = parse_bool(value)?
            }
            // serial vs concurrent per-owner RPC fan-out (perf ablation;
            // the batch stream is byte-identical either way)
            "concurrent_rpc" => {
                self.cluster.concurrent_rpc = parse_bool(value)?
            }
            "cache_budget_bytes" => {
                self.cluster.cache_budget_bytes = parse_usize()?
            }
            "cache_admission" => {
                self.cluster.cache_admission =
                    CacheAdmission::parse(value)?
            }
            // lock stripes the cache is split into (prefetch inserts
            // vs worker lookups); must be >= 1
            "cache_shards" => {
                let n = parse_usize()?;
                if n == 0 {
                    bail!("cache_shards must be >= 1");
                }
                self.cluster.cache_shards = n;
            }
            // lookahead batches the predictive prefetcher pulls ahead
            // of demand (0 = off); the batch stream is byte-identical
            // for any value
            "prefetch_depth" => {
                self.cluster.prefetch_depth = parse_usize()?
            }
            // bounded-staleness window for learnable embeddings; 0
            // (strict) is byte-identical to an uncached client
            "embedding_staleness" => {
                self.cluster.embedding_staleness = parse_usize()?
            }
            // primary/backup KV shard replication with transparent
            // failover (docs/DESIGN.md §12); off = a dead server is the
            // §8 typed error
            "replicate_kv" => {
                self.cluster.replicate_kv = parse_bool(value)?
            }
            "etype_fanouts" => {
                // per-etype fanout weights, e.g. "2,1,1,1"; each layer's
                // K is split proportionally (schema weights when unset)
                self.cluster.etype_fanouts = value
                    .split(',')
                    .map(|w| {
                        w.trim()
                            .parse::<usize>()
                            .with_context(|| format!("{key}={value}"))
                    })
                    .collect::<Result<_>>()?;
            }
            "variant" => self.train.variant = value.to_string(),
            "lr" => self.train.lr = value.parse()?,
            "epochs" => self.train.epochs = parse_usize()?,
            "max_steps" => self.train.max_steps = parse_usize()?,
            // skip each epoch's short tail batch (the loader's DGL-style
            // drop_last; max_steps=0 inherits the shorter epoch length)
            "drop_last" => self.train.drop_last = parse_bool(value)?,
            "eval" => self.train.eval_each_epoch = parse_bool(value)?,
            "seed" => {
                self.train.seed = value.parse()?;
                self.cluster.seed = value.parse()?;
            }
            "pipeline" => {
                self.train.pipeline.mode = match value {
                    "sync" => PipelineMode::Sync,
                    "async" => PipelineMode::Async,
                    "nonstop" => PipelineMode::AsyncNonstop,
                    _ => bail!("pipeline must be sync|async|nonstop"),
                }
            }
            "cpu_prefetch" => {
                self.train.pipeline.cpu_prefetch_depth = parse_usize()?
            }
            "gpu_prefetch" => {
                self.train.pipeline.gpu_prefetch_depth = parse_usize()?
            }
            // sampling workers per trainer (stage 1-4 parallelism); the
            // batch stream is byte-identical for any value
            "num_workers" => {
                let n = parse_usize()?;
                if n == 0 {
                    bail!("num_workers must be >= 1");
                }
                self.train.pipeline.num_workers = n;
            }
            // fault tolerance (docs/DESIGN.md §8): rank 0 snapshots the
            // run every N steps; `resume_from=` replays the exact stream
            "checkpoint_every" => {
                self.train.checkpoint_every = parse_usize()?
            }
            "checkpoint_dir" => {
                self.train.checkpoint_dir = value.to_string()
            }
            "resume_from" => self.train.resume_from = value.to_string(),
            // SGD momentum over the post-all-reduce mean gradient; 0.0
            // is plain SGD (byte-identical to the pre-momentum trainer)
            "momentum" => {
                let m: f32 =
                    value.parse().with_context(|| format!("{key}={value}"))?;
                if !(0.0..1.0).contains(&m) {
                    bail!("momentum must be in [0, 1), got {value}");
                }
                self.train.momentum = m;
            }
            // keep only the newest N checkpoints (0 = keep everything)
            "checkpoint_keep" => {
                self.train.checkpoint_keep = parse_usize()?
            }
            // elastic membership (docs/DESIGN.md §9): planned resize
            // schedule "E:W,E:W,..." — at cumulative epoch boundary E,
            // reshape the membership to W trainers
            "elastic" => {
                self.train.elastic =
                    crate::coordinator::parse_elastic_schedule(value)?
            }
            // demote machines whose compute step time persistently
            // exceeds straggler_factor x the fleet median
            "demote_stragglers" => {
                self.train.demote_stragglers = parse_bool(value)?
            }
            "straggler_factor" => {
                let f: f64 =
                    value.parse().with_context(|| format!("{key}={value}"))?;
                if f <= 1.0 {
                    bail!("straggler_factor must be > 1, got {value}");
                }
                self.train.straggler_factor = f;
            }
            "straggler_patience" => {
                let p = parse_usize()?;
                if p == 0 {
                    bail!("straggler_patience must be >= 1");
                }
                self.train.straggler_patience = p;
            }
            // seconds of epoch-boundary silence before a rank is
            // declared dead and its machine demoted
            "heartbeat_timeout" => {
                let secs: f64 =
                    value.parse().with_context(|| format!("{key}={value}"))?;
                if !(secs > 0.0) {
                    bail!("heartbeat_timeout must be > 0, got {value}");
                }
                self.train.heartbeat_timeout =
                    std::time::Duration::from_secs_f64(secs);
            }
            _ => bail!(
                "unknown key {key:?}; valid: dataset feat_dim classes \
                 num_rels dataset_seed machines trainers partitioner \
                 multi_constraint two_level emulate_network \
                 concurrent_rpc cache_budget_bytes cache_admission \
                 cache_shards prefetch_depth embedding_staleness \
                 replicate_kv etype_fanouts variant lr epochs max_steps \
                 drop_last eval \
                 seed pipeline cpu_prefetch gpu_prefetch num_workers \
                 checkpoint_every checkpoint_dir resume_from momentum \
                 checkpoint_keep elastic demote_stragglers \
                 straggler_factor straggler_patience heartbeat_timeout"
            ),
        }
        Ok(())
    }

    /// Parse a sequence of `key=value` arguments over the defaults.
    pub fn from_args<I: IntoIterator<Item = String>>(
        args: I,
    ) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        for a in args {
            let (k, v) = a
                .split_once('=')
                .with_context(|| format!("expected key=value, got {a:?}"))?;
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }

    /// Parse a config file: one `key=value` per line, `#` comments and
    /// blank lines skipped. This is what `scripts/launch.sh` hands to
    /// every machine process, so one file defines the whole cluster.
    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let mut cfg = RunConfig::default();
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').with_context(|| {
                format!("{path}:{}: expected key=value, got {line:?}", i + 1)
            })?;
            cfg.set(k.trim(), v.trim())
                .with_context(|| format!("{path}:{}", i + 1))?;
        }
        Ok(cfg)
    }

    /// DistDGL-v1 baseline preset: synchronous pipeline, 1-level split.
    pub fn preset_distdgl_v1(mut self) -> Self {
        self.train.pipeline.mode = PipelineMode::Sync;
        self.cluster.two_level = false;
        self
    }

    /// Euler baseline preset: random partitioning, process-only
    /// parallelism (no sampling thread ⇒ sync pipeline), 1-level split.
    pub fn preset_euler(mut self) -> Self {
        self.cluster.partitioner = Partitioner::Random;
        self.cluster.multi_constraint = false;
        self.cluster.two_level = false;
        self.train.pipeline.mode = PipelineMode::Sync;
        self
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        _ => bail!("expected bool, got {v:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_overrides() {
        let cfg = RunConfig::from_args(
            [
                "machines=4",
                "trainers=2",
                "dataset=rmat:5000:20000",
                "pipeline=sync",
                "lr=0.05",
                "two_level=false",
            ]
            .map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.cluster.n_machines, 4);
        assert_eq!(cfg.dataset.n_nodes, 5000);
        assert_eq!(cfg.train.pipeline.mode, PipelineMode::Sync);
        assert_eq!(cfg.train.lr, 0.05);
        assert!(!cfg.cluster.two_level);
    }

    #[test]
    fn paper_dataset_with_scale() {
        let cfg = RunConfig::from_args(
            ["dataset=ogbn-products@2000".to_string()],
        )
        .unwrap();
        assert_eq!(cfg.dataset.n_nodes, 1200);
        assert_eq!(cfg.dataset.feat_dim, 100);
    }

    #[test]
    fn cache_knobs_parse() {
        let cfg = RunConfig::from_args(
            ["cache_budget_bytes=1048576", "cache_admission=degree:8"]
                .map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.cluster.cache_budget_bytes, 1 << 20);
        assert_eq!(
            cfg.cluster.cache_admission,
            CacheAdmission::Degree(Some(8))
        );
        let off = RunConfig::from_args(
            ["cache_budget_bytes=0".to_string()],
        )
        .unwrap();
        assert_eq!(off.cluster.cache_budget_bytes, 0);
        assert!(RunConfig::from_args(
            ["cache_admission=lru".to_string()]
        )
        .is_err());
        // default: cache on, admit-all
        let d = RunConfig::default();
        assert!(d.cluster.cache_budget_bytes > 0);
        assert_eq!(d.cluster.cache_admission, CacheAdmission::All);
    }

    #[test]
    fn prefetch_and_staleness_knobs_parse() {
        // defaults: prefetch off, strict embeddings, one stripe
        let d = RunConfig::default();
        assert_eq!(d.cluster.prefetch_depth, 0);
        assert_eq!(d.cluster.embedding_staleness, 0);
        assert_eq!(d.cluster.cache_shards, 1);
        let cfg = RunConfig::from_args(
            [
                "prefetch_depth=8",
                "embedding_staleness=4",
                "cache_shards=16",
            ]
            .map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.cluster.prefetch_depth, 8);
        assert_eq!(cfg.cluster.embedding_staleness, 4);
        assert_eq!(cfg.cluster.cache_shards, 16);
        // rejection: a shardless cache is nonsensical, and non-numeric
        // values fail with the offending pair in the message
        for bad in [
            "cache_shards=0",
            "cache_shards=x",
            "prefetch_depth=deep",
            "embedding_staleness=-1",
        ] {
            assert!(
                RunConfig::from_args([bad.to_string()]).is_err(),
                "{bad} should be rejected"
            );
        }
    }

    #[test]
    fn etype_fanouts_parse() {
        let cfg = RunConfig::from_args(
            ["dataset=mag-lsc@100000", "etype_fanouts=2,1,1,1"]
                .map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.cluster.etype_fanouts, vec![2, 1, 1, 1]);
        assert_eq!(cfg.dataset.num_rels, 4);
        assert_eq!(cfg.dataset.schema().n_ntypes(), 3);
        // num_rels aligns the dataset with a compiled variant
        let aligned = RunConfig::from_args(
            ["dataset=mag-lsc@100000", "num_rels=3"].map(String::from),
        )
        .unwrap();
        assert_eq!(aligned.dataset.num_rels, 3);
        assert_eq!(aligned.dataset.schema().n_etypes(), 3);
        assert!(RunConfig::from_args(
            ["etype_fanouts=2,x".to_string()]
        )
        .is_err());
        // default: no override (schema weights apply)
        assert!(RunConfig::default().cluster.etype_fanouts.is_empty());
    }

    #[test]
    fn replicate_kv_parses_and_defaults_off() {
        assert!(!RunConfig::default().cluster.replicate_kv);
        let cfg =
            RunConfig::from_args(["replicate_kv=true".to_string()])
                .unwrap();
        assert!(cfg.cluster.replicate_kv);
        assert!(
            RunConfig::from_args(["replicate_kv=maybe".to_string()])
                .is_err()
        );
    }

    #[test]
    fn worker_and_rpc_knobs_parse() {
        let d = RunConfig::default();
        assert_eq!(d.train.pipeline.num_workers, 1);
        assert!(d.cluster.concurrent_rpc);
        let cfg = RunConfig::from_args(
            ["num_workers=4", "concurrent_rpc=false"].map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.train.pipeline.num_workers, 4);
        assert!(!cfg.cluster.concurrent_rpc);
        assert!(
            RunConfig::from_args(["num_workers=0".to_string()]).is_err()
        );
    }

    #[test]
    fn drop_last_parses_and_defaults_off() {
        assert!(!RunConfig::default().train.drop_last);
        let cfg = RunConfig::from_args(["drop_last=true".to_string()])
            .unwrap();
        assert!(cfg.train.drop_last);
        assert!(RunConfig::from_args(
            ["drop_last=maybe".to_string()]
        )
        .is_err());
    }

    #[test]
    fn checkpoint_knobs_parse_and_default_off() {
        let d = RunConfig::default();
        assert_eq!(d.train.checkpoint_every, 0);
        assert!(d.train.checkpoint_dir.is_empty());
        assert!(d.train.resume_from.is_empty());
        let cfg = RunConfig::from_args(
            [
                "checkpoint_every=50",
                "checkpoint_dir=/tmp/ckpts",
                "resume_from=/tmp/ckpts/ckpt_00000100.ckpt",
            ]
            .map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.train.checkpoint_every, 50);
        assert_eq!(cfg.train.checkpoint_dir, "/tmp/ckpts");
        assert_eq!(
            cfg.train.resume_from,
            "/tmp/ckpts/ckpt_00000100.ckpt"
        );
        assert!(RunConfig::from_args(
            ["checkpoint_every=x".to_string()]
        )
        .is_err());
    }

    #[test]
    fn elastic_knobs_parse_and_default_off() {
        use crate::coordinator::ResizeEvent;
        use std::time::Duration;
        let d = RunConfig::default();
        assert_eq!(d.train.momentum, 0.0);
        assert_eq!(d.train.checkpoint_keep, 0);
        assert!(d.train.elastic.is_empty());
        assert!(!d.train.demote_stragglers);
        assert!(!d.train.is_elastic());
        let cfg = RunConfig::from_args(
            [
                "momentum=0.9",
                "checkpoint_keep=3",
                "elastic=2:2,4:8",
                "demote_stragglers=true",
                "straggler_factor=2.5",
                "straggler_patience=1",
                "heartbeat_timeout=0.5",
            ]
            .map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.train.momentum, 0.9);
        assert_eq!(cfg.train.checkpoint_keep, 3);
        assert_eq!(
            cfg.train.elastic,
            vec![
                ResizeEvent { boundary: 2, world: 2 },
                ResizeEvent { boundary: 4, world: 8 },
            ]
        );
        assert!(cfg.train.demote_stragglers);
        assert_eq!(cfg.train.straggler_factor, 2.5);
        assert_eq!(cfg.train.straggler_patience, 1);
        assert_eq!(
            cfg.train.heartbeat_timeout,
            Duration::from_millis(500)
        );
        assert!(cfg.train.is_elastic());
        // validation: each knob rejects out-of-domain values
        for bad in [
            "momentum=1.0",
            "momentum=-0.1",
            "elastic=2",
            "elastic=0:4",
            "straggler_factor=1.0",
            "straggler_patience=0",
            "heartbeat_timeout=0",
        ] {
            assert!(
                RunConfig::from_args([bad.to_string()]).is_err(),
                "{bad} should be rejected"
            );
        }
    }

    #[test]
    fn config_file_parses_with_comments_and_blanks() {
        let dir = std::env::temp_dir().join("distdglv2_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.cfg");
        std::fs::write(
            &path,
            "# cluster shape\n\
             machines = 2\n\
             trainers=1\n\
             \n\
             dataset=rmat:4000:16000  # small smoke graph\n\
             epochs=2\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.cluster.n_machines, 2);
        assert_eq!(cfg.cluster.trainers_per_machine, 1);
        assert_eq!(cfg.dataset.n_nodes, 4000);
        assert_eq!(cfg.train.epochs, 2);
        // a bad line reports file:line
        std::fs::write(&path, "machines=2\nnonsense\n").unwrap();
        let err = RunConfig::from_file(path.to_str().unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains(":2"), "{err}");
        assert!(
            RunConfig::from_file("/nonexistent/run.cfg").is_err()
        );
    }

    #[test]
    fn unknown_key_lists_valid_ones() {
        let err = RunConfig::from_args(["bogus=1".to_string()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("valid:"));
    }

    #[test]
    fn presets_flip_the_right_knobs() {
        let v1 = RunConfig::default().preset_distdgl_v1();
        assert_eq!(v1.train.pipeline.mode, PipelineMode::Sync);
        assert!(!v1.cluster.two_level);
        assert_eq!(v1.cluster.partitioner, Partitioner::Metis);
        let euler = RunConfig::default().preset_euler();
        assert_eq!(euler.cluster.partitioner, Partitioner::Random);
    }
}
