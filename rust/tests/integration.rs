//! Integration tests: the whole stack composed — partition → deploy →
//! asynchronous pipeline → distributed sampling → KVStore → PJRT train
//! steps → ring all-reduce → evaluation. These require `make artifacts`.

use std::path::PathBuf;

use distdglv2::api::{DistGraph, DistNodeDataLoader};
use distdglv2::cluster::{Cluster, ClusterSpec, Partitioner};
use distdglv2::config::RunConfig;
use distdglv2::graph::DatasetSpec;
use distdglv2::pipeline::PipelineMode;
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::trainer::{self, DeviceExecutor, TrainConfig};

fn artifacts() -> PathBuf {
    // tests run from the crate root
    let d = artifacts_dir();
    assert!(
        d.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    d
}

fn small_dataset(seed: u64) -> distdglv2::graph::Dataset {
    let mut spec = DatasetSpec::new("itest", 6000, 30_000);
    spec.seed = seed;
    spec.generate()
}

fn quick_train(cluster: &Cluster, steps: usize, mode: PipelineMode) -> trainer::TrainReport {
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        lr: 0.3,
        epochs: 1,
        max_steps: steps,
        eval_each_epoch: true,
        ..Default::default()
    };
    cfg.pipeline.mode = mode;
    trainer::train(cluster, &cfg).expect("training failed")
}

#[test]
fn two_machine_training_loss_decreases() {
    let d = small_dataset(1);
    let cluster =
        Cluster::deploy(&d, ClusterSpec::new(2, 2), artifacts()).unwrap();
    let report = quick_train(&cluster, 8, PipelineMode::AsyncNonstop);
    assert_eq!(report.steps, 8);
    let first = report.loss_curve[0];
    let last = *report.loss_curve.last().unwrap();
    assert!(
        last < first,
        "loss did not decrease: {first} -> {last} ({:?})",
        report.loss_curve
    );
    assert!(report.loss_curve.iter().all(|l| l.is_finite()));
    // distributed training moved bytes
    assert!(report.net_bytes > 0);
    assert!(report.pcie_bytes > 0);
}

#[test]
fn replicas_agree_after_allreduce_and_accuracy_beats_chance() {
    let d = small_dataset(2);
    let cluster =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let report = quick_train(&cluster, 20, PipelineMode::AsyncNonstop);
    // after enough steps accuracy must clearly beat 1/16 chance
    let acc = report.final_val_acc.unwrap();
    assert!(acc > 2.0 / 16.0, "val acc {acc} barely above chance");
}

#[test]
fn pipeline_modes_give_equivalent_convergence() {
    // Sync vs AsyncNonstop is a *performance* difference; statistically the
    // training should reach similar loss (not bit-identical: batch order
    // differs). Compare mean of last 4 losses.
    let d = small_dataset(3);
    let tail = |r: &trainer::TrainReport| {
        let n = r.loss_curve.len();
        r.loss_curve[n - 4..].iter().map(|&x| x as f64).sum::<f64>() / 4.0
    };
    let c1 =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let sync_tail = tail(&quick_train(&c1, 16, PipelineMode::Sync));
    let c2 =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let async_tail = tail(&quick_train(&c2, 16, PipelineMode::AsyncNonstop));
    assert!(
        (sync_tail - async_tail).abs() < 0.8,
        "sync {sync_tail} vs async {async_tail}"
    );
}

#[test]
fn worker_pool_training_is_bit_identical_to_single_worker() {
    // end-to-end tentpole gate: 4 sampling workers + concurrent RPC
    // fan-out feed the exact same batches, so the whole training run —
    // losses, byte counters, final params — matches the single-worker
    // serial-RPC run bit for bit
    let d = small_dataset(6);
    let c1 = Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts())
        .unwrap();
    let mut serial_spec = ClusterSpec::new(2, 1);
    serial_spec.concurrent_rpc = false;
    let c2 = Cluster::deploy(&d, serial_spec, artifacts()).unwrap();
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 1,
        max_steps: 6,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::AsyncNonstop;
    cfg.pipeline.num_workers = 4;
    let pooled = trainer::train(&c1, &cfg).expect("worker-pool train");
    cfg.pipeline.num_workers = 1;
    let single = trainer::train(&c2, &cfg).expect("single-worker train");
    assert_eq!(
        pooled.loss_curve, single.loss_curve,
        "worker pool / concurrent RPC changed the training stream"
    );
    assert_eq!(pooled.final_params, single.final_params);
    // (remote_feature_rows is NOT compared: with the default cache
    // shared across 4 workers, hit/miss attribution depends on which
    // worker touched a row first — the payload bytes never do.)
}

#[test]
fn prefetch_training_is_bit_identical_to_prefetch_off() {
    // the prefetch tentpole's e2e gate: a deep lookahead over a sharded
    // cache only moves rows *earlier* — in strict embedding mode (the
    // default) losses and final params match the prefetch-off run bit
    // for bit, and the report proves the lookahead actually ran
    let d = small_dataset(7);
    let c_off = Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts())
        .unwrap();
    let mut pf_spec = ClusterSpec::new(2, 1);
    pf_spec.prefetch_depth = 8;
    pf_spec.cache_shards = 4;
    let c_on = Cluster::deploy(&d, pf_spec, artifacts()).unwrap();
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 1,
        max_steps: 6,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::AsyncNonstop;
    cfg.pipeline.num_workers = 2;
    let off = trainer::train(&c_off, &cfg).expect("prefetch-off train");
    let on = trainer::train(&c_on, &cfg).expect("prefetch-on train");
    assert_eq!(
        off.loss_curve, on.loss_curve,
        "prefetch changed the training stream"
    );
    assert_eq!(off.final_params, on.final_params);
    assert_eq!(off.cache_prefetch_issued, 0);
    assert!(
        on.cache_prefetch_issued > 0,
        "prefetcher never issued a pull"
    );
}

#[test]
fn metis_moves_fewer_remote_feature_rows_than_random() {
    let d = small_dataset(4);
    let mut metis = ClusterSpec::new(2, 1);
    metis.partitioner = Partitioner::Metis;
    let mut random = ClusterSpec::new(2, 1);
    random.partitioner = Partitioner::Random;
    let cm = Cluster::deploy(&d, metis, artifacts()).unwrap();
    let cr = Cluster::deploy(&d, random, artifacts()).unwrap();
    let rm = quick_train(&cm, 8, PipelineMode::AsyncNonstop);
    let rr = quick_train(&cr, 8, PipelineMode::AsyncNonstop);
    assert!(
        (rm.remote_feature_rows as f64)
            < 0.8 * rr.remote_feature_rows as f64,
        "metis {} vs random {} remote rows",
        rm.remote_feature_rows,
        rr.remote_feature_rows
    );
}

#[test]
fn link_prediction_trains() {
    let d = small_dataset(5);
    let cluster =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let cfg = TrainConfig {
        variant: "sage_lp_dev".into(),
        lr: 0.1,
        epochs: 1,
        max_steps: 6,
        ..Default::default()
    };
    let report = trainer::train(&cluster, &cfg).unwrap();
    assert!(report.loss_curve.iter().all(|l| l.is_finite()));
    assert!(
        report.loss_curve.last().unwrap() < &report.loss_curve[0],
        "{:?}",
        report.loss_curve
    );
}

#[test]
fn gat_and_rgcn_variants_train() {
    let d = small_dataset(6);
    for (variant, lr) in [("gat_nc_dev", 0.5f32), ("rgcn_nc_dev", 0.3)] {
        let cluster =
            Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts())
                .unwrap();
        let cfg = TrainConfig {
            variant: variant.into(),
            lr,
            epochs: 1,
            max_steps: 5,
            ..Default::default()
        };
        let report = trainer::train(&cluster, &cfg).unwrap();
        assert!(
            report.loss_curve.iter().all(|l| l.is_finite()),
            "{variant}: {:?}",
            report.loss_curve
        );
    }
}

/// The heterogeneous headline path: a mag-lsc-shaped typed dataset (3
/// node types, typed relations) trains the RGCN variant end to end with
/// per-etype fanouts, per-ntype feature tables, and *sampled* — never
/// synthesized — relation ids reaching the executable.
#[test]
fn mag_lsc_rgcn_end_to_end_hetero() {
    let m = Manifest::load(&artifacts()).unwrap();
    // prefer the 4-relation mag-shaped variant; fall back to the
    // 3-relation dev variant (aligning the dataset) on older artifacts
    let (vname, v) = match m.variant("rgcn_nc_mag") {
        Ok(v) => ("rgcn_nc_mag", v),
        Err(_) => ("rgcn_nc_dev", m.variant("rgcn_nc_dev").unwrap()),
    };
    let mut dspec = DatasetSpec::paper_table1("mag-lsc", 100_000);
    dspec.feat_dim = v.feat_dim; // dev-shape features
    dspec.num_classes = v.num_classes;
    dspec.num_rels = v.num_rels; // align etypes with the compiled variant
    dspec.train_frac = 0.5; // enough labeled papers at this scale
    let d = dspec.generate();
    assert!(d.schema.n_ntypes() == 3 && d.schema.n_etypes() == v.num_rels);
    d.graph.validate_schema(&d.schema).unwrap();

    let cluster =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    // per-ntype feature tables with independent dims
    assert_eq!(cluster.features.names.len(), 3);
    assert!(cluster.features.dims[1] < cluster.features.dims[0]);
    // per-etype fanout split of the variant's layer budgets
    let plan = cluster.fanout_plan(&v.fanouts);
    assert_eq!(plan.layer(1).len(), v.num_rels);
    assert_eq!(plan.layer(1).iter().sum::<usize>(), v.fanouts[0]);

    let cfg = TrainConfig {
        variant: vname.into(),
        lr: 0.3,
        epochs: 1,
        max_steps: 6,
        ..Default::default()
    };
    let report = trainer::train(&cluster, &cfg).unwrap();
    assert!(
        report.loss_curve.iter().all(|l| l.is_finite()),
        "{:?}",
        report.loss_curve
    );
    // the executable consumed real typed batches: at least two distinct
    // relation types were sampled and metered on the way in
    let nonzero = report
        .etype_sampled_edges
        .iter()
        .filter(|&&c| c > 0)
        .count();
    assert!(
        nonzero >= 2,
        "expected a typed edge mix, got {:?}",
        report.etype_sampled_edges
    );
}

/// The api_redesign acceptance gate, end to end: a hand-written loop
/// over `DistGraph` + `DistNodeDataLoader` + an explicit device handle
/// reproduces `trainer::train`'s losses and final parameters exactly —
/// the loader streams the same bytes the trainer's internal pipeline
/// consumed pre-refactor (1 trainer, so the all-reduce is the identity).
#[test]
fn custom_loop_over_the_api_matches_trainer_train() {
    let d = small_dataset(7);
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        lr: 0.3,
        epochs: 1,
        max_steps: 6,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::Sync;
    let c1 =
        Cluster::deploy(&d, ClusterSpec::new(1, 1), artifacts()).unwrap();
    let report = trainer::train(&c1, &cfg).unwrap();

    // a fresh, identically-deployed cluster and the open-coded loop
    let c2 =
        Cluster::deploy(&d, ClusterSpec::new(1, 1), artifacts()).unwrap();
    let graph = DistGraph::new(&c2);
    let device = DeviceExecutor::spawn(
        c2.artifacts.clone(),
        cfg.variant.clone(),
        None,
    )
    .unwrap();
    let spec = device.spec().unwrap();
    let mut params = device.initial_params().unwrap();
    let mut loader = DistNodeDataLoader::builder(&graph, &spec)
        .seed(cfg.seed) // trainer rank 0 mixes to exactly cfg.seed
        .pipeline(cfg.pipeline.clone())
        .build()
        .unwrap();
    let handle = device.handle();
    let mut losses = Vec::new();
    for _ in 0..report.steps {
        let batch = loader.next_batch();
        let (loss, spent) =
            handle.train_reusing(&mut params, batch, cfg.lr).unwrap();
        loader.recycle(spent);
        losses.push(loss);
    }
    assert_eq!(losses, report.loss_curve, "loss curves diverged");
    assert_eq!(params, report.final_params, "parameters diverged");
}

/// Regression for the epoch-boundary off-by-one: a max_steps cap one
/// past an epoch boundary must surface as a 1-step final epoch window,
/// and drop_last must shrink the epoch length max_steps=0 inherits —
/// both via the loader's len().
#[test]
fn max_steps_and_drop_last_interact_via_loader_len() {
    let d = small_dataset(8);
    let cluster =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let m = Manifest::load(&artifacts()).unwrap();
    let v = m.variant("sage_nc_dev").unwrap();
    let n = cluster.train_sets[0].len();
    let spe = n.div_ceil(v.batch);

    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 5,
        max_steps: spe + 1,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::Sync;
    let report = trainer::train(&cluster, &cfg).unwrap();
    assert_eq!(report.steps, spe + 1);
    assert_eq!(report.loss_curve.len(), spe + 1);
    assert_eq!(
        report.epochs.len(),
        2,
        "one step past the boundary must open a second epoch window"
    );
    // the 1-step window's mean is that step's (trainer-mean) loss
    let tail = report.epochs[1].mean_loss;
    assert!(
        (tail - report.loss_curve[spe] as f64).abs() < 1e-6,
        "tail window {tail} != step loss {}",
        report.loss_curve[spe]
    );

    if n > v.batch && n % v.batch != 0 {
        let mut cfg2 = TrainConfig {
            variant: "sage_nc_dev".into(),
            epochs: 1,
            drop_last: true,
            ..Default::default()
        };
        cfg2.pipeline.mode = PipelineMode::Sync;
        let r2 = trainer::train(&cluster, &cfg2).unwrap();
        assert_eq!(
            r2.steps,
            n / v.batch,
            "max_steps=0 must inherit the drop_last epoch length"
        );
    }
}

/// The fault-tolerance tentpole, end to end: train N steps straight
/// (checkpointing along the way), then pretend the job died — a fresh
/// deployment resuming from the mid-run checkpoint must replay the
/// remaining steps with an identical loss stream and identical final
/// parameters, bit for bit.
#[test]
fn checkpoint_resume_is_byte_identical_to_straight_run() {
    let d = small_dataset(9);
    let dir = std::env::temp_dir().join("ddgl_resume_itest");
    let _ = std::fs::remove_dir_all(&dir);
    let c1 =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 1,
        max_steps: 8,
        checkpoint_every: 3,
        checkpoint_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::AsyncNonstop;
    cfg.pipeline.num_workers = 2;
    let straight = trainer::train(&c1, &cfg).expect("straight run");
    assert_eq!(straight.ft_checkpoints, 2, "steps 3 and 6");
    assert!(straight.ft_checkpoint_bytes > 0);
    assert_eq!(straight.resumed_at, 0);

    // "crash" after step 6: redeploy and resume from the latest
    // checkpoint, replaying global steps 6..8
    let c2 =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let mut rcfg = cfg.clone();
    rcfg.checkpoint_every = 0;
    rcfg.checkpoint_dir = String::new();
    rcfg.resume_from = distdglv2::ft::Checkpoint::path_for(&dir, 6)
        .to_string_lossy()
        .into_owned();
    let resumed = trainer::train(&c2, &rcfg).expect("resumed run");
    assert_eq!(resumed.resumed_at, 6);
    assert_eq!(resumed.steps, 2);
    assert!(resumed.ft_recovery_secs > 0.0);
    assert_eq!(
        resumed.loss_curve,
        straight.loss_curve[6..].to_vec(),
        "resumed loss stream diverged from the uninterrupted run"
    );
    assert_eq!(
        resumed.final_params, straight.final_params,
        "resumed final parameters diverged"
    );

    // a seed-mismatched checkpoint must be refused, not silently replay
    // a different stream
    rcfg.seed = cfg.seed ^ 1;
    assert!(trainer::train(&c2, &rcfg).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Transient injected outages heal through bounded retries without
/// changing a single byte of the run; the retry work is reported.
#[test]
fn transient_faults_heal_and_training_is_unchanged() {
    use distdglv2::ft::{FailWindow, FaultPlan};
    let d = small_dataset(10);
    let c1 =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let c2 =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let mut plan = FaultPlan::new();
    // transient windows over call-counter slots 5..7 on BOTH machines:
    // the two trainer threads interleave their remote RPCs
    // non-deterministically, so covering every machine pins the injected
    // failure count (exactly 2 per subsystem) regardless of which
    // trainer's request lands in the window
    for m in 0..2 {
        plan.kv_outages.push(FailWindow::transient(m, 5, 2));
        plan.sampler_outages.push(FailWindow::transient(m, 5, 2));
    }
    plan.backoff = std::time::Duration::ZERO;
    c2.set_fault_plan(std::sync::Arc::new(plan));
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 1,
        max_steps: 6,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::Sync;
    let clean = trainer::train(&c1, &cfg).expect("clean run");
    let faulty = trainer::train(&c2, &cfg).expect("faulty run");
    assert_eq!(clean.loss_curve, faulty.loss_curve);
    assert_eq!(clean.final_params, faulty.final_params);
    assert!(faulty.ft_retries >= 4, "retries {}", faulty.ft_retries);
    assert!(faulty.ft_injected_failures >= 4);
    assert_eq!(clean.ft_retries, 0);
}

/// The replication tentpole end to end (docs/DESIGN.md §12): a KV
/// server that dies permanently mid-run fails over to its standby
/// replica and the whole run — losses, final params — matches a
/// fault-free deployment byte for byte, across pipeline modes, worker
/// pools, and the prefetching cache.
#[test]
fn kv_server_death_mid_run_is_byte_identical_with_replication() {
    use distdglv2::ft::{FailWindow, FaultPlan};
    let d = small_dataset(12);
    for (mode, workers, prefetch) in [
        (PipelineMode::Sync, 1usize, false),
        (PipelineMode::Async, 2, false),
        (PipelineMode::AsyncNonstop, 2, true),
    ] {
        let clean =
            Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts())
                .unwrap();
        let mut spec = ClusterSpec::new(2, 1);
        spec.replicate_kv = true;
        if prefetch {
            spec.prefetch_depth = 8;
            spec.cache_shards = 4;
        }
        let faulty = Cluster::deploy(&d, spec, artifacts()).unwrap();
        let mut plan = FaultPlan::new();
        plan.backoff = std::time::Duration::ZERO;
        // machine 0's server drops dead a few remote pulls in
        plan.kv_outages.push(FailWindow::permanent(0, 3));
        faulty.set_fault_plan(std::sync::Arc::new(plan));
        let mut cfg = TrainConfig {
            variant: "sage_nc_dev".into(),
            epochs: 1,
            max_steps: 8,
            ..Default::default()
        };
        cfg.pipeline.mode = mode;
        cfg.pipeline.num_workers = workers;
        let want = trainer::train(&clean, &cfg).expect("clean run");
        let got = trainer::train(&faulty, &cfg)
            .expect("replicated run should survive the dead server");
        assert_eq!(
            want.loss_curve, got.loss_curve,
            "failover changed the training stream ({mode:?})"
        );
        assert_eq!(
            want.final_params, got.final_params,
            "failover changed the final params ({mode:?})"
        );
        assert_eq!(got.ft_failovers, 1, "expected exactly one failover");
        assert!(got.ft_replica_bytes > 0);
        assert_eq!(want.ft_failovers, 0);
    }
}

/// Without `replicate_kv` the very same injection keeps its §8
/// contract: the run drains to the typed `ServerDown` error instead of
/// hanging or fabricating data.
#[test]
fn kv_server_death_without_replication_drains_typed() {
    use distdglv2::ft::{FailWindow, FaultPlan};
    let d = small_dataset(12);
    let c = Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts())
        .unwrap();
    let mut plan = FaultPlan::new();
    plan.backoff = std::time::Duration::ZERO;
    plan.kv_outages.push(FailWindow::permanent(0, 3));
    c.set_fault_plan(std::sync::Arc::new(plan));
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 1,
        max_steps: 8,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::Sync;
    let err = trainer::train(&c, &cfg)
        .expect_err("unreplicated dead server must fail the run");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("kv") && msg.contains("machine 0"),
        "expected the typed kv ServerDown error, got: {msg}"
    );
}

/// The elastic-membership tentpole, end to end: a 4-trainer run with a
/// planned shrink to world 2 at the first epoch boundary must (a) write
/// a reconfiguration checkpoint carrying the new membership, and (b)
/// continue with a batch stream — and parameters — byte-identical to a
/// fresh 2-trainer deployment resumed from that same checkpoint.
#[test]
fn elastic_shrink_matches_fresh_resume_end_to_end() {
    use distdglv2::coordinator::parse_elastic_schedule;
    use distdglv2::ft::Checkpoint;
    let d = small_dataset(11);
    let dir = std::env::temp_dir().join("ddgl_elastic_itest");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let big =
        Cluster::deploy(&d, ClusterSpec::new(2, 2), artifacts()).unwrap();
    let m = Manifest::load(&artifacts()).unwrap();
    let v = m.variant("sage_nc_dev").unwrap();
    let spe = big.train_sets[0].len().div_ceil(v.batch);
    let total = 3 * spe;

    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 3,
        max_steps: total,
        checkpoint_dir: dir.to_string_lossy().into_owned(),
        elastic: parse_elastic_schedule("1:2").unwrap(),
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::AsyncNonstop;
    cfg.pipeline.num_workers = 2;
    let elastic = trainer::train(&big, &cfg).expect("elastic run");
    assert_eq!(elastic.steps, total);
    assert_eq!(elastic.ft_reconfigurations, 1);
    assert_eq!(elastic.ft_demotions, 0, "a planned resize demotes nobody");
    let rc = &elastic.reconfigurations[0];
    assert_eq!((rc.boundary, rc.at_step), (1, spe));
    assert_eq!((rc.from_world, rc.to_world), (4, 2));
    assert!(rc.demoted_machines.is_empty());
    // the reconfiguration checkpoint records the membership it moves to
    let ck =
        Checkpoint::load(&Checkpoint::path_for(&dir, spe as u64)).unwrap();
    let view = ck.membership.expect("membership record");
    assert_eq!(view.world_size(), 2);
    // the report's ft line surfaces the reconfiguration
    let line = distdglv2::benchsuite::locality_summary(&elastic);
    assert!(line.contains("reconfigs 1"), "{line}");

    // fresh smaller world resumed from the boundary checkpoint: the
    // classic (non-elastic) driver must replay the identical tail
    let small =
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts()).unwrap();
    let mut rcfg = cfg.clone();
    rcfg.elastic.clear();
    rcfg.checkpoint_dir = String::new();
    rcfg.resume_from = Checkpoint::path_for(&dir, spe as u64)
        .to_string_lossy()
        .into_owned();
    let resumed = trainer::train(&small, &rcfg).expect("fresh resume");
    assert_eq!(resumed.resumed_at, spe as u64);
    assert_eq!(
        resumed.loss_curve,
        elastic.loss_curve[spe..].to_vec(),
        "post-shrink stream diverged from the fresh smaller-world resume"
    );
    assert_eq!(
        resumed.final_params, elastic.final_params,
        "post-shrink parameters diverged"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Straggler demotion, end to end: an injected per-step compute
/// slowdown on machine 1 makes its heartbeats exceed the straggler
/// threshold; with patience 1 the coordinator demotes the machine at
/// the first epoch boundary and the survivors finish the run.
#[test]
fn straggler_demotion_completes_and_is_reported() {
    use distdglv2::ft::FaultPlan;
    let d = small_dataset(12);
    let cluster =
        Cluster::deploy(&d, ClusterSpec::new(2, 2), artifacts()).unwrap();
    let mut plan = FaultPlan::new();
    plan.step_slowdowns
        .push((1, std::time::Duration::from_millis(100)));
    cluster.set_fault_plan(std::sync::Arc::new(plan));
    let m = Manifest::load(&artifacts()).unwrap();
    let v = m.variant("sage_nc_dev").unwrap();
    let spe = cluster.train_sets[0].len().div_ceil(v.batch);
    let total = 2 * spe;

    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        epochs: 2,
        max_steps: total,
        demote_stragglers: true,
        straggler_factor: 2.0,
        straggler_patience: 1,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::AsyncNonstop;
    let report = trainer::train(&cluster, &cfg).expect("demotion run");
    assert_eq!(report.steps, total, "survivors must finish the run");
    assert_eq!(report.ft_reconfigurations, 1);
    assert_eq!(report.ft_demotions, 1);
    let rc = &report.reconfigurations[0];
    assert_eq!(rc.demoted_machines, vec![1]);
    assert_eq!((rc.from_world, rc.to_world), (4, 2));
    assert_eq!(rc.at_step, spe);
    assert!(report.loss_curve.iter().all(|l| l.is_finite()));
    let line = distdglv2::benchsuite::locality_summary(&report);
    assert!(
        line.contains("reconfigs 1") && line.contains("demotions 1"),
        "{line}"
    );
}

#[test]
fn run_config_round_trips_through_cluster() {
    let cfg = RunConfig::from_args(
        ["dataset=rmat:4000:16000", "machines=2", "trainers=1", "max_steps=3"]
            .map(String::from),
    )
    .unwrap();
    let d = cfg.dataset.generate();
    let cluster =
        Cluster::deploy(&d, cfg.cluster.clone(), artifacts()).unwrap();
    let report = trainer::train(&cluster, &cfg.train).unwrap();
    assert_eq!(report.steps, 3);
}

#[test]
fn manifest_variants_cover_all_models() {
    let m = Manifest::load(&artifacts()).unwrap();
    for v in ["sage_nc_dev", "sage_lp_dev", "gat_nc_dev", "rgcn_nc_dev"] {
        assert!(m.variants.contains_key(v), "missing {v}");
    }
}
