//! End-to-end validation driver (the repository's headline experiment).
//!
//! Exercises every layer of the system on a real small workload: a
//! 100K-node / 1M-edge power-law graph with label-correlated features,
//! a 4-machine x 2-trainer simulated cluster, the full preprocessing
//! pipeline (multi-constraint METIS partition → relabel → halo → KVStore
//! load → 2-level workload split), and several hundred synchronous
//! data-parallel training steps of AOT-compiled GraphSAGE with the
//! non-stop asynchronous mini-batch pipeline. Logs the loss curve and
//! epoch/validation metrics; the run is recorded in EXPERIMENTS.md.
//!
//! Run:  make artifacts && cargo run --release --example e2e_train

use std::time::Instant;

use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let t_all = Instant::now();

    // ~100K nodes, ~1M directed edges after symmetrization
    let mut dspec = DatasetSpec::new("e2e-100k", 100_000, 500_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.3; // enough labeled nodes for a few hundred steps
    println!("== generating dataset ==");
    let t = Instant::now();
    let dataset = dspec.generate();
    println!(
        "{} nodes, {} edges, {} train nodes  ({:.2}s)",
        dataset.n_nodes(),
        dataset.graph.n_edges(),
        dataset
            .nodes_with(distdglv2::graph::SplitTag::Train)
            .len(),
        t.elapsed().as_secs_f64()
    );

    println!("\n== deploying 4x2 cluster ==");
    let cluster = Cluster::deploy(
        &dataset,
        ClusterSpec::new(4, 2),
        artifacts_dir(),
    )?;
    let s = &cluster.stats;
    println!(
        "partition {:.2}s (edge cut {} = {:.1}%, imbalance {:.3}) | halo+relabel \
         {:.2}s | kv load {:.2}s",
        s.partition_secs,
        s.edge_cut,
        100.0 * cluster.edge_cut_frac(),
        s.imbalance,
        s.build_secs,
        s.load_secs
    );
    for p in &cluster.partitions {
        println!(
            "  machine {}: {} core + {} halo vertices, {} edges",
            p.part_id,
            p.n_core,
            p.n_halo(),
            p.graph.n_edges()
        );
    }

    println!("\n== training GraphSAGE (300+ steps, sync SGD, 8 trainers) ==");
    let cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        lr: 0.3,
        epochs: 10,
        max_steps: 300,
        eval_each_epoch: true,
        ..Default::default()
    };
    let report = trainer::train(&cluster, &cfg)?;

    println!("loss curve (every 10th step):");
    for (i, l) in report.loss_curve.iter().enumerate().step_by(10) {
        println!("  step {i:>4}  loss {l:.4}");
    }
    println!("\nepoch summary:");
    for e in &report.epochs {
        println!(
            "  epoch {:>2}  mean loss {:.4}  {:.2}s",
            e.epoch, e.mean_loss, e.secs
        );
    }
    println!(
        "\n== results ==\n{} steps in {:.1}s = {:.1} steps/s ({} trainers)\n\
         final val accuracy {:.3} (chance {:.3})\n\
         network traffic {:.1} MiB ({} msgs, modeled time {:.1} ms)\n\
         PCIe traffic {:.1} MiB (modeled {:.1} ms)\n\
         remote feature rows {} | total wall clock {:.1}s",
        report.steps,
        report.total_secs,
        report.steps as f64 / report.total_secs,
        cluster.n_trainers(),
        report.final_val_acc.unwrap_or(f64::NAN),
        1.0 / cluster.num_classes as f64,
        report.net_bytes as f64 / (1 << 20) as f64,
        cluster.cost.network_msgs(),
        cluster.cost.modeled_network_secs() * 1e3,
        report.pcie_bytes as f64 / (1 << 20) as f64,
        cluster.cost.modeled_pcie_secs() * 1e3,
        report.remote_feature_rows,
        t_all.elapsed().as_secs_f64(),
    );

    let first = report.loss_curve[..10].iter().sum::<f32>() / 10.0;
    let last = report.loss_curve[report.loss_curve.len() - 10..]
        .iter()
        .sum::<f32>()
        / 10.0;
    anyhow::ensure!(last < first, "loss did not decrease: {first} -> {last}");
    anyhow::ensure!(
        report.final_val_acc.unwrap_or(0.0) > 2.0 / 16.0,
        "accuracy did not beat chance"
    );
    println!("\nE2E VALIDATION PASSED (loss {first:.3} -> {last:.3})");
    Ok(())
}
