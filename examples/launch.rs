//! Multi-process localhost launcher (docs/DESIGN.md §11).
//!
//! One invocation per machine process, all reading the same config file:
//!
//! ```text
//! cargo run --release --example launch -- run.cfg \
//!     --machine 0 --port-base 29500 &
//! cargo run --release --example launch -- run.cfg \
//!     --machine 1 --port-base 29500 &
//! ```
//!
//! or the whole cluster in one process over the in-process backend:
//!
//! ```text
//! cargo run --release --example launch -- run.cfg --inproc
//! ```
//!
//! Every process deploys the same deterministic cluster replica, joins
//! the rendezvous service (hosted by machine 0), serves its KVStore
//! shard over RPC, and runs the ordinary `DistGraph` +
//! `DistNodeDataLoader` training loop — the loader code path is
//! byte-identical to the single-process one; only the parameter plane
//! (ring all-reduce) and the control plane (rendezvous barrier,
//! heartbeats, shutdown) cross process boundaries. `scripts/launch.sh`
//! asserts the printed `MACHINE_RESULT` lines (batch-stream hashes,
//! final loss, parameter hash) are identical between the in-process and
//! multi-process TCP runs.
//!
//! The model step is a deterministic softmax-regression surrogate over
//! the batch's layer-0 feature rows, so the run needs no compiled
//! device artifacts (the CI smoke job has none); swap in
//! `DeviceExecutor` for the compiled GNN variants.
//!
//! **Chaos mode** (docs/DESIGN.md §12): `scripts/launch.sh N P --chaos`
//! kills one machine process mid-run and restarts it, asserting the
//! final `MACHINE_RESULT` lines still match the fault-free in-process
//! reference byte for byte. Three flags cooperate:
//!
//! - `--chaos-exit` — the victim (never machine 0, which hosts the
//!   rendezvous) trains epoch 0 then `std::process::exit`s abruptly
//!   *before* the epoch-0 barrier: no shutdown goodbye, no KV drain,
//!   listener and ring endpoints vanish mid-cluster while the
//!   survivors park inside the barrier (every epoch-0 ring frame is
//!   already consumed — the all-reduce is synchronous — so nothing
//!   can be lost into the dead socket);
//! - `--chaos-resume` — the restarted victim redeploys
//!   deterministically, reclaims its machine id with
//!   `RendezvousClient::rejoin`, re-imports its KV shard from the
//!   standby's `replica<m>::*` tables over real RPC (requires
//!   `replicate_kv=1`), recovers its epoch-0 trainer state by replaying
//!   the whole world over a local in-process ring (byte-identical to
//!   what the wire produced, per the backend-identity invariant), then
//!   trains epoch 1+ over the real TCP transport;
//! - `--chaos` — survivors only stretch their ring receive timeout so
//!   the victim's restart window reads as latency, not failure.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};
use distdglv2::api::{DistGraph, DistNodeDataLoader, Seeds};
use distdglv2::cluster::Cluster;
use distdglv2::config::RunConfig;
use distdglv2::coordinator::rendezvous::{
    RendezvousClient, RendezvousServer,
};
use distdglv2::coordinator::{
    CoordinatorConfig, Decision, MembershipView,
};
use distdglv2::ft::{parse_replica_table, replica_table};
use distdglv2::net::rpc::{serve_kv, RpcClient};
use distdglv2::net::tcp::{tcp_transport, TcpConfig};
use distdglv2::net::{CostModel, Transport};
use distdglv2::runtime::executable::HostBatch;
use distdglv2::runtime::manifest::{artifacts_dir, VariantSpec};
use distdglv2::sampler::compact::{ModelKind, TaskKind};
use distdglv2::trainer::allreduce::Participant;
use distdglv2::trainer::AllReduceGroup;

/// Endpoint-space layout shared by every process (and both backends):
/// ring endpoints first, then per-machine control / kv-serve /
/// kv-client endpoints, then the rendezvous server on machine 0.
struct Layout {
    world: usize,
    n_mach: usize,
}

impl Layout {
    fn control(&self, m: usize) -> u32 {
        (self.world + m) as u32
    }
    fn kv_serve(&self, m: usize) -> u32 {
        (self.world + self.n_mach + m) as u32
    }
    fn kv_client(&self, m: usize) -> u32 {
        (self.world + 2 * self.n_mach + m) as u32
    }
    fn server(&self) -> u32 {
        (self.world + 3 * self.n_mach) as u32
    }
    fn n_endpoints(&self) -> usize {
        self.world + 3 * self.n_mach + 1
    }
    /// Process (= machine) hosting endpoint `e`.
    fn proc_of(&self, e: usize, per: usize) -> usize {
        if e < self.world {
            e / per
        } else if e == self.server() as usize {
            0
        } else {
            (e - self.world) % self.n_mach
        }
    }
}

/// Role this process plays in a `--chaos` run (docs/DESIGN.md §12).
#[derive(Clone, Copy, PartialEq, Eq)]
enum ChaosMode {
    /// Ordinary run — fail fast on any peer loss.
    Off,
    /// Survivor in a chaos run: stretch the ring receive timeout so
    /// the victim's kill-to-restart window reads as latency.
    Tolerate,
    /// Victim, first life: exit abruptly right before the epoch-0
    /// barrier (the survivors park inside it until the restart).
    Exit,
    /// Victim, second life: rejoin, re-import the shard, replay
    /// epoch 0 locally, continue epoch 1+ over the wire.
    Resume,
}

struct Args {
    config: Option<String>,
    machine: Option<usize>,
    port_base: u16,
    inproc: bool,
    chaos: ChaosMode,
}

fn parse_args() -> Result<Args> {
    let mut args = Args {
        config: None,
        machine: None,
        port_base: 29500,
        inproc: false,
        chaos: ChaosMode::Off,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--machine" => {
                let v = it.next().context("--machine needs a value")?;
                args.machine = Some(v.parse().context("--machine")?);
            }
            "--port-base" => {
                let v = it.next().context("--port-base needs a value")?;
                args.port_base = v.parse().context("--port-base")?;
            }
            "--inproc" => args.inproc = true,
            "--chaos" => args.chaos = ChaosMode::Tolerate,
            "--chaos-exit" => args.chaos = ChaosMode::Exit,
            "--chaos-resume" => args.chaos = ChaosMode::Resume,
            flag if flag.starts_with("--") => {
                bail!(
                    "unknown flag {flag}; usage: launch [config.cfg] \
                     [--machine M --port-base P \
                     [--chaos|--chaos-exit|--chaos-resume] | --inproc]"
                );
            }
            path => args.config = Some(path.to_string()),
        }
    }
    ensure!(
        args.machine.is_none() || !args.inproc,
        "--machine and --inproc are mutually exclusive"
    );
    ensure!(
        args.chaos == ChaosMode::Off || !args.inproc,
        "chaos flags are for the multi-process TCP backend"
    );
    Ok(args)
}

/// The surrogate's variant spec: shapes only (no HLO/artifacts), enough
/// for the loader to build the usual padded 2-layer batches.
fn surrogate_vspec(cfg: &RunConfig) -> VariantSpec {
    let batch = 16usize;
    VariantSpec {
        name: "launch-surrogate".into(),
        model: ModelKind::Sage,
        task: TaskKind::NodeClassification,
        batch,
        fanouts: vec![3, 3],
        layer_nodes: vec![
            (batch * 16).next_multiple_of(128),
            (batch * 4).next_multiple_of(128),
            batch.next_multiple_of(128),
        ],
        feat_dim: cfg.dataset.feat_dim,
        num_classes: cfg.dataset.num_classes,
        num_heads: 1,
        num_rels: 1,
        param_shapes: Vec::new(),
        train_inputs: Vec::new(),
        eval_inputs: Vec::new(),
        train_hlo: String::new(),
        eval_hlo: String::new(),
        params_bin: String::new(),
    }
}

/// One softmax-regression SGD step over the batch's labeled seed rows
/// (layer-0 rows `0..nL` are the seeds — `compact::to_block` places dst
/// nodes first). Pure f32 arithmetic in a fixed order, so the loss and
/// the updated params are bit-identical across backends and processes.
fn surrogate_step(
    params: &mut [Vec<f32>],
    batch: &HostBatch,
    fd: usize,
    nc: usize,
    lr: f32,
) -> f32 {
    let (w, b) = params.split_at_mut(1);
    let (w, b) = (&mut w[0], &mut b[0]);
    let mut gw = vec![0.0f32; fd * nc];
    let mut gb = vec![0.0f32; nc];
    let mut loss = 0.0f32;
    let mut cnt = 0.0f32;
    for (i, (&y, &mk)) in
        batch.labels.iter().zip(&batch.label_mask).enumerate()
    {
        if mk <= 0.0 || y < 0 || y as usize >= nc {
            continue;
        }
        let y = y as usize;
        let x = &batch.feats[i * fd..(i + 1) * fd];
        let mut logits: Vec<f32> = (0..nc)
            .map(|c| {
                let mut v = b[c];
                for (k, &xk) in x.iter().enumerate() {
                    v += xk * w[k * nc + c];
                }
                v
            })
            .collect();
        let mx =
            logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut z = 0.0f32;
        for l in logits.iter_mut() {
            *l = (*l - mx).exp();
            z += *l;
        }
        loss -= (logits[y] / z).ln();
        cnt += 1.0;
        for (c, &e) in logits.iter().enumerate() {
            let g = e / z - if c == y { 1.0 } else { 0.0 };
            gb[c] += g;
            for (k, &xk) in x.iter().enumerate() {
                gw[k * nc + c] += g * xk;
            }
        }
    }
    if cnt == 0.0 {
        return 0.0;
    }
    let s = lr / cnt;
    for (wv, g) in w.iter_mut().zip(&gw) {
        *wv -= s * g;
    }
    for (bv, g) in b.iter_mut().zip(&gb) {
        *bv -= s * g;
    }
    loss / cnt
}

fn fnv1a(h: &mut u64, x: u64) {
    for byte in x.to_le_bytes() {
        *h ^= byte as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

fn hash_params(params: &[Vec<f32>]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for p in params {
        for v in p {
            fnv1a(&mut h, v.to_bits() as u64);
        }
    }
    h
}

/// One rank's epoch: drain the loader once, hashing the batch stream
/// and stepping + all-reducing every batch. Shared by the live epoch
/// loop and the `--chaos-resume` epoch-0 replay so the two produce
/// bit-identical state.
#[allow(clippy::too_many_arguments)]
fn rank_epoch(
    loader: &mut DistNodeDataLoader,
    p: &mut Participant,
    prm: &mut [Vec<f32>],
    curve: &mut Vec<f32>,
    hash: &mut u64,
    fd: usize,
    nc: usize,
    lr: f32,
) -> Result<()> {
    for batch in &mut *loader {
        let (input_nodes, seeds, _blocks) = batch.unpack();
        for &n in input_nodes {
            fnv1a(hash, n as u64);
        }
        for &n in seeds {
            fnv1a(hash, n as u64);
        }
        let loss = surrogate_step(prm, &batch, fd, nc, lr);
        p.allreduce_params(prm)
            .map_err(|e| anyhow::anyhow!("all-reduce: {e}"))?;
        curve.push(loss);
    }
    Ok(())
}

/// `--chaos-resume` state recovery: replay epoch 0 for the WHOLE world
/// over a fresh in-process ring. Batch composition is pure in
/// (seed, epoch, batch index) and the in-process and TCP backends are
/// byte-identical, so this reproduces exactly the params, loss curve,
/// and stream hashes the victim held when it died — without touching
/// the wire the survivors are currently training epoch 1 on. Returns
/// (loaders, params, losses, hashes) for `ranks` only, with the
/// loaders re-armed for epoch 1.
type RankState =
    (Vec<DistNodeDataLoader>, Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>, Vec<u64>);

fn replay_epoch0(
    cluster: &Cluster,
    cfg: &RunConfig,
    vspec: &VariantSpec,
    layout: &Layout,
    ranks: &[usize],
) -> Result<RankState> {
    let per = cfg.cluster.trainers_per_machine;
    let world = layout.world;
    let endpoint_machine: Vec<u32> = (0..layout.n_endpoints())
        .map(|e| layout.proc_of(e, per) as u32)
        .collect();
    let transport = Transport::with_mapping(
        endpoint_machine,
        Arc::new(CostModel::default()),
    );
    let group = AllReduceGroup::from_transport(transport, world);
    let graph = DistGraph::new(cluster);
    let (fd, nc) = (vspec.feat_dim, vspec.num_classes);
    let mut loaders = Vec::with_capacity(world);
    let mut participants = Vec::with_capacity(world);
    for r in 0..world {
        loaders.push(
            DistNodeDataLoader::builder(&graph, vspec)
                .rank(r)
                .seeds(Seeds::Train)
                .seed(cfg.train.seed ^ ((r as u64) << 17))
                .build()?,
        );
        participants.push(group.endpoint(r).map_err(|e| {
            anyhow::anyhow!("claiming replay ring rank {r}: {e}")
        })?);
    }
    let mut params: Vec<Vec<Vec<f32>>> = (0..world)
        .map(|_| vec![vec![0.0f32; fd * nc], vec![0.0f32; nc]])
        .collect();
    let mut losses: Vec<Vec<f32>> = vec![Vec::new(); world];
    let mut hashes: Vec<u64> = vec![0xcbf2_9ce4_8422_2325u64; world];
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (((loader, p), prm), (curve, hash)) in loaders
            .iter_mut()
            .zip(participants.iter_mut())
            .zip(params.iter_mut())
            .zip(losses.iter_mut().zip(hashes.iter_mut()))
        {
            handles.push(s.spawn(move || {
                rank_epoch(
                    loader,
                    p,
                    prm,
                    curve,
                    hash,
                    fd,
                    nc,
                    cfg.train.lr,
                )
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("replay thread panicked"))
            .collect::<Result<Vec<()>>>()
    })?;
    // keep only this machine's ranks
    let (lo, n) = (ranks[0], ranks.len());
    fn window<T>(mut v: Vec<T>, lo: usize, n: usize) -> Vec<T> {
        v.drain(..lo);
        v.truncate(n);
        v
    }
    Ok((
        window(loaders, lo, n),
        window(params, lo, n),
        window(losses, lo, n),
        window(hashes, lo, n),
    ))
}

struct MachineResult {
    machine: usize,
    /// Per local rank: (rank, batch-stream hash).
    streams: Vec<(usize, u64)>,
    param_hash: u64,
    loss_start: f32,
    final_loss: f32,
}

impl MachineResult {
    /// The line `scripts/launch.sh` compares verbatim between backends.
    fn line(&self) -> String {
        let streams: Vec<String> = self
            .streams
            .iter()
            .map(|(r, h)| format!("{r}:{h:016x}"))
            .collect();
        format!(
            "MACHINE_RESULT m={} streams={} param_hash={:016x} \
             loss_start={:.6} final_loss={:.6}",
            self.machine,
            streams.join(","),
            self.param_hash,
            self.loss_start,
            self.final_loss,
        )
    }
}

/// Everything one machine process does after deploy: serve its KV
/// shard, join the rendezvous, cross-check a peer's shard over RPC,
/// train its local ranks with per-epoch wire barriers, say goodbye.
#[allow(clippy::too_many_arguments)]
fn run_machine(
    cluster: &Cluster,
    transport: &Arc<Transport>,
    group: &Arc<AllReduceGroup>,
    cfg: &RunConfig,
    vspec: &VariantSpec,
    layout: &Layout,
    m: usize,
    chaos: ChaosMode,
) -> Result<MachineResult> {
    let per = cfg.cluster.trainers_per_machine;
    let n_mach = layout.n_mach;
    let resume = chaos == ChaosMode::Resume;

    // data plane: serve this machine's KVStore shard over the wire
    let running = Arc::new(AtomicBool::new(true));
    let kv_thread = serve_kv(
        transport.endpoint(layout.kv_serve(m)),
        cluster.kv.servers[m].clone(),
        running.clone(),
    );

    // control plane: join the rendezvous (machine id = our
    // preference); a restarted victim reclaims its previous id
    // instead — a plain Hello would collide with the reserved one
    let mut rdv = if resume {
        RendezvousClient::rejoin(
            transport.endpoint(layout.control(m)),
            layout.server(),
            m as u32,
            Duration::from_secs(60),
        )?
    } else {
        RendezvousClient::join(
            transport.endpoint(layout.control(m)),
            layout.server(),
            Some(m as u32),
            Duration::from_secs(60),
        )?
    };
    ensure!(
        rdv.machine() as usize == m,
        "rendezvous assigned machine {} to process {m}",
        rdv.machine()
    );
    let ranks = rdv.my_ranks();
    ensure!(ranks == (m * per..(m + 1) * per).collect::<Vec<_>>());

    // start barrier: every process deployed + serving before anyone
    // pulls. A resumed victim already crossed it in its first life —
    // arriving again would desync the per-epoch barrier rounds.
    if !resume {
        match rdv.barrier_all(&ranks).map_err(anyhow::Error::from)? {
            Decision::Continue => {}
            Decision::Reconfigure(v) => {
                bail!(
                    "membership changed before training started: {v:?}"
                )
            }
        }
    }

    let peer = (m + 1) % n_mach;
    if resume {
        // restart re-import (docs/DESIGN.md §12): pull this machine's
        // primary shards back from the standby's replica tables over
        // real RPC. The launcher's KV data is static, so the
        // deterministic redeploy must agree byte for byte — the
        // re-import doubles as a cross-check of the replica plane.
        let mut rpc =
            RpcClient::new(transport.endpoint(layout.kv_client(m)));
        let (mut tables, mut bytes) = (0usize, 0usize);
        for (name, dim, local) in cluster.kv.servers[m].export_shards()
        {
            if parse_replica_table(&name).is_some() {
                continue; // our copy of the previous machine's backup
            }
            let n_local = local.len() / dim.max(1);
            let locals: Vec<u32> = (0..n_local as u32).collect();
            let backup = replica_table(m as u32, &name);
            let mut rows = Vec::with_capacity(local.len());
            for chunk in locals.chunks(1024) {
                let (rdim, part) = rpc
                    .kv_pull(layout.kv_serve(peer), &backup, chunk)
                    .map_err(anyhow::Error::from)?;
                ensure!(rdim == dim, "replica {backup} dim mismatch");
                rows.extend_from_slice(&part);
            }
            ensure!(
                rows == local,
                "replica re-import of {name} from machine {peer} \
                 disagrees with the deterministic redeploy"
            );
            bytes += rows.len() * 4;
            tables += 1;
            cluster.kv.servers[m].import_shard(&name, dim, rows);
        }
        println!(
            "CHAOS_REIMPORT m={m} standby={peer} tables={tables} \
             bytes={bytes}"
        );
    } else if n_mach > 1 {
        // cross-process data-plane check: pull label rows from the
        // next machine's server over real RPC and compare against our
        // replica
        let mut rpc =
            RpcClient::new(transport.endpoint(layout.kv_client(m)));
        let locals: Vec<u32> = (0..4).collect();
        let (dim, remote) = rpc
            .kv_pull(layout.kv_serve(peer), "label", &locals)
            .map_err(anyhow::Error::from)?;
        let mut local = vec![0.0f32; locals.len() * dim];
        cluster.kv.servers[peer]
            .read_rows("label", &locals, &mut local)
            .map_err(anyhow::Error::from)?;
        ensure!(
            remote == local,
            "RPC pull from machine {peer} disagrees with the replica"
        );
        println!("KV_CROSSCHECK m={m} peer={peer} rows={} ok", dim * 4);
    }

    // the unmodified loader path: one DistNodeDataLoader per local
    // rank. A resumed victim recovers its epoch-0 state by replaying
    // the whole world locally; its loaders come back re-armed for
    // epoch 1.
    let graph = DistGraph::new(cluster);
    let fd = vspec.feat_dim;
    let nc = vspec.num_classes;
    let (mut loaders, mut params, mut losses, mut hashes) = if resume {
        let state = replay_epoch0(cluster, cfg, vspec, layout, &ranks)?;
        println!(
            "CHAOS_REPLAY m={m} epoch=0 steps={}",
            state.2[0].len()
        );
        state
    } else {
        let mut loaders: Vec<DistNodeDataLoader> = Vec::new();
        for &r in &ranks {
            loaders.push(
                DistNodeDataLoader::builder(&graph, vspec)
                    .rank(r)
                    .seeds(Seeds::Train)
                    .seed(cfg.train.seed ^ ((r as u64) << 17))
                    .build()?,
            );
        }
        (
            loaders,
            ranks
                .iter()
                .map(|_| vec![vec![0.0f32; fd * nc], vec![0.0f32; nc]])
                .collect(),
            ranks.iter().map(|_| Vec::new()).collect(),
            ranks.iter().map(|_| 0xcbf2_9ce4_8422_2325u64).collect(),
        )
    };
    let mut participants = Vec::new();
    for &r in &ranks {
        participants.push(group.endpoint(r).map_err(|e| {
            anyhow::anyhow!("claiming ring rank {r}: {e}")
        })?);
    }
    for (p, curve) in participants.iter_mut().zip(&losses) {
        if chaos != ChaosMode::Off {
            // a kill + restart must read as latency, not peer death
            p.recv_timeout = Duration::from_secs(180);
        }
        if resume {
            // line the ring-frame tags back up with the rounds the
            // survivors are on (one all-reduce per replayed step)
            p.set_seq(curve.len() as u64);
        }
    }

    if resume {
        // the victim died between epoch-0 training and the epoch-0
        // barrier, so the survivors are parked inside that barrier
        // right now (no ring frames in flight — the synchronization
        // that makes the kill window safe). Arrive and release them.
        for &r in &ranks {
            rdv.heartbeat(r, 0.0).map_err(anyhow::Error::from)?;
        }
        match rdv.barrier_all(&ranks).map_err(anyhow::Error::from)? {
            Decision::Continue => {}
            Decision::Reconfigure(v) => {
                bail!("membership shrank during the restart: {v:?}")
            }
        }
    }

    let start_epoch = usize::from(resume);
    for epoch in start_epoch..cfg.train.epochs {
        let t_epoch = std::time::Instant::now();
        // local ranks train concurrently; the ring syncs every step
        // across ALL processes, so global steps stay aligned
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (((loader, p), prm), (curve, hash)) in loaders
                .iter_mut()
                .zip(participants.iter_mut())
                .zip(params.iter_mut())
                .zip(losses.iter_mut().zip(hashes.iter_mut()))
            {
                handles.push(s.spawn(move || {
                    rank_epoch(
                        loader,
                        p,
                        prm,
                        curve,
                        hash,
                        fd,
                        nc,
                        cfg.train.lr,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("trainer thread panicked"))
                .collect::<Result<Vec<()>>>()
        })?;
        if chaos == ChaosMode::Exit && epoch == 0 {
            // die abruptly BEFORE the epoch-0 barrier: epoch 0's ring
            // all-reduces are synchronous, so every trainer frame has
            // been consumed, and the survivors will park inside the
            // barrier until the restarted process (--chaos-resume)
            // arrives in our place — no frame can be lost into a dead
            // socket. No shutdown goodbye, no KV drain: the listener,
            // shard, and ring endpoints vanish mid-cluster.
            println!("CHAOS_EXIT m={m} epoch={epoch}");
            use std::io::Write as _;
            std::io::stdout().flush().ok();
            std::process::exit(0);
        }
        // epoch boundary over the wire: heartbeats + barrier
        let secs = t_epoch.elapsed().as_secs_f64();
        for &r in &ranks {
            rdv.heartbeat(r, secs).map_err(anyhow::Error::from)?;
        }
        match rdv.barrier_all(&ranks).map_err(anyhow::Error::from)? {
            Decision::Continue => {}
            Decision::Reconfigure(v) => {
                // a peer process died mid-run; the fixed-membership
                // launcher reports and stops (the in-process elastic
                // driver handles live reconfiguration)
                bail!(
                    "membership shrank to {:?} at epoch {epoch} — a \
                     peer process is gone",
                    v.machines
                )
            }
        }
    }

    rdv.shutdown().map_err(anyhow::Error::from)?;
    running.store(false, Ordering::SeqCst);
    kv_thread.join().expect("kv serve thread panicked");

    // after the final all-reduce every rank's params are identical;
    // hash the first local rank's copy
    let curve = &losses[0];
    ensure!(!curve.is_empty(), "loader yielded no training batches");
    let k = curve.len().clamp(1, 5);
    Ok(MachineResult {
        machine: m,
        streams: ranks.iter().copied().zip(hashes).collect(),
        param_hash: hash_params(&params[0]),
        loss_start: curve[..k].iter().sum::<f32>() / k as f32,
        final_loss: curve[curve.len() - k..].iter().sum::<f32>()
            / k as f32,
    })
}

fn main() -> Result<()> {
    let args = parse_args()?;
    let cfg = match &args.config {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig {
            dataset: distdglv2::graph::DatasetSpec::new(
                "launch-default",
                4000,
                16_000,
            ),
            ..RunConfig::default()
        },
    };
    let n_mach = cfg.cluster.n_machines;
    let per = cfg.cluster.trainers_per_machine;
    let world = n_mach * per;
    let layout = Layout { world, n_mach };
    if let Some(m) = args.machine {
        ensure!(m < n_mach, "--machine {m} >= machines {n_mach}");
    }

    if args.chaos != ChaosMode::Off {
        ensure!(n_mach >= 2, "chaos needs at least 2 machines");
    }
    if matches!(args.chaos, ChaosMode::Exit | ChaosMode::Resume) {
        let m = args.machine.context("chaos victim needs --machine")?;
        ensure!(
            m != 0,
            "machine 0 hosts the rendezvous server and cannot be the \
             chaos victim"
        );
        ensure!(
            cfg.train.epochs >= 2,
            "a kill-and-restart run needs at least 2 epochs"
        );
        ensure!(
            cfg.cluster.replicate_kv,
            "chaos restart needs replicate_kv=1 (the shard is \
             re-imported from its standby's replica tables)"
        );
    }

    println!(
        "launch: {n_mach} machines x {per} trainers, {} epochs, \
         backend={}{}",
        cfg.train.epochs,
        if args.inproc { "in-process" } else { "tcp" },
        match args.chaos {
            ChaosMode::Off => "",
            ChaosMode::Tolerate => ", chaos=tolerate",
            ChaosMode::Exit => ", chaos=exit",
            ChaosMode::Resume => ", chaos=resume",
        },
    );

    // deterministic replicated deployment: every process builds the
    // same dataset and cluster from the config's seeds
    let dataset = cfg.dataset.generate();
    let cluster = Arc::new(Cluster::deploy(
        &dataset,
        cfg.cluster.clone(),
        artifacts_dir(),
    )?);
    let vspec = surrogate_vspec(&cfg);

    let cost = Arc::new(CostModel::default());
    let endpoint_machine: Vec<u32> = (0..layout.n_endpoints())
        .map(|e| layout.proc_of(e, per) as u32)
        .collect();
    // rendezvous liveness: reaping is for crashed processes, not slow
    // epochs — keep the timeout far above any smoke epoch
    let co_cfg = CoordinatorConfig {
        heartbeat_timeout: Duration::from_secs(120),
        ..Default::default()
    };

    let mut results: Vec<MachineResult> = Vec::new();
    if args.inproc {
        // whole cluster in this process over the in-process backend —
        // the reference run the TCP launch must match byte for byte
        let transport =
            Transport::with_mapping(endpoint_machine, cost);
        let group =
            AllReduceGroup::from_transport(transport.clone(), world);
        let server = RendezvousServer::new(
            transport.endpoint(layout.server()),
            MembershipView::initial(n_mach, per),
            co_cfg,
            n_mach,
        );
        let server_thread = std::thread::spawn(move || server.run());
        let outs = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for m in 0..n_mach {
                let (cluster, transport, group) =
                    (&cluster, &transport, &group);
                let (cfg, vspec, layout) = (&cfg, &vspec, &layout);
                handles.push(s.spawn(move || {
                    run_machine(
                        cluster,
                        transport,
                        group,
                        cfg,
                        vspec,
                        layout,
                        m,
                        ChaosMode::Off,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("machine thread panicked"))
                .collect::<Result<Vec<_>>>()
        })?;
        results.extend(outs);
        let boundaries = server_thread
            .join()
            .expect("rendezvous server panicked");
        println!("rendezvous: {boundaries} epoch boundaries decided");
    } else {
        let m = args.machine.context(
            "pass --machine M (one process per machine) or --inproc",
        )?;
        let mut tcfg = TcpConfig::localhost(m, n_mach, args.port_base);
        tcfg.endpoint_proc = (0..layout.n_endpoints())
            .map(|e| layout.proc_of(e, per))
            .collect();
        tcfg.machine_of = endpoint_machine;
        let transport =
            tcp_transport(tcfg, cost).map_err(anyhow::Error::from)?;
        let group =
            AllReduceGroup::from_transport(transport.clone(), world);
        // machine 0 hosts the rendezvous service
        let server_thread = (m == 0).then(|| {
            let server = RendezvousServer::new(
                transport.endpoint(layout.server()),
                MembershipView::initial(n_mach, per),
                co_cfg,
                n_mach,
            );
            std::thread::spawn(move || server.run())
        });
        results.push(run_machine(
            &cluster,
            &transport,
            &group,
            &cfg,
            &vspec,
            &layout,
            m,
            args.chaos,
        )?);
        if let Some(h) = server_thread {
            let boundaries =
                h.join().expect("rendezvous server panicked");
            println!(
                "rendezvous: {boundaries} epoch boundaries decided"
            );
        }
    }

    results.sort_by_key(|r| r.machine);
    for r in &results {
        println!("{}", r.line());
    }
    let r0 = &results[0];
    ensure!(
        r0.final_loss < r0.loss_start,
        "loss did not decrease: {} -> {}",
        r0.loss_start,
        r0.final_loss
    );
    println!("LAUNCH OK");
    Ok(())
}
