//! Multi-process localhost launcher (docs/DESIGN.md §11).
//!
//! One invocation per machine process, all reading the same config file:
//!
//! ```text
//! cargo run --release --example launch -- run.cfg \
//!     --machine 0 --port-base 29500 &
//! cargo run --release --example launch -- run.cfg \
//!     --machine 1 --port-base 29500 &
//! ```
//!
//! or the whole cluster in one process over the in-process backend:
//!
//! ```text
//! cargo run --release --example launch -- run.cfg --inproc
//! ```
//!
//! Every process deploys the same deterministic cluster replica, joins
//! the rendezvous service (hosted by machine 0), serves its KVStore
//! shard over RPC, and runs the ordinary `DistGraph` +
//! `DistNodeDataLoader` training loop — the loader code path is
//! byte-identical to the single-process one; only the parameter plane
//! (ring all-reduce) and the control plane (rendezvous barrier,
//! heartbeats, shutdown) cross process boundaries. `scripts/launch.sh`
//! asserts the printed `MACHINE_RESULT` lines (batch-stream hashes,
//! final loss, parameter hash) are identical between the in-process and
//! multi-process TCP runs.
//!
//! The model step is a deterministic softmax-regression surrogate over
//! the batch's layer-0 feature rows, so the run needs no compiled
//! device artifacts (the CI smoke job has none); swap in
//! `DeviceExecutor` for the compiled GNN variants.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};
use distdglv2::api::{DistGraph, DistNodeDataLoader, Seeds};
use distdglv2::cluster::Cluster;
use distdglv2::config::RunConfig;
use distdglv2::coordinator::rendezvous::{
    RendezvousClient, RendezvousServer,
};
use distdglv2::coordinator::{
    CoordinatorConfig, Decision, MembershipView,
};
use distdglv2::net::rpc::{serve_kv, RpcClient};
use distdglv2::net::tcp::{tcp_transport, TcpConfig};
use distdglv2::net::{CostModel, Transport};
use distdglv2::runtime::executable::HostBatch;
use distdglv2::runtime::manifest::{artifacts_dir, VariantSpec};
use distdglv2::sampler::compact::{ModelKind, TaskKind};
use distdglv2::trainer::AllReduceGroup;

/// Endpoint-space layout shared by every process (and both backends):
/// ring endpoints first, then per-machine control / kv-serve /
/// kv-client endpoints, then the rendezvous server on machine 0.
struct Layout {
    world: usize,
    n_mach: usize,
}

impl Layout {
    fn control(&self, m: usize) -> u32 {
        (self.world + m) as u32
    }
    fn kv_serve(&self, m: usize) -> u32 {
        (self.world + self.n_mach + m) as u32
    }
    fn kv_client(&self, m: usize) -> u32 {
        (self.world + 2 * self.n_mach + m) as u32
    }
    fn server(&self) -> u32 {
        (self.world + 3 * self.n_mach) as u32
    }
    fn n_endpoints(&self) -> usize {
        self.world + 3 * self.n_mach + 1
    }
    /// Process (= machine) hosting endpoint `e`.
    fn proc_of(&self, e: usize, per: usize) -> usize {
        if e < self.world {
            e / per
        } else if e == self.server() as usize {
            0
        } else {
            (e - self.world) % self.n_mach
        }
    }
}

struct Args {
    config: Option<String>,
    machine: Option<usize>,
    port_base: u16,
    inproc: bool,
}

fn parse_args() -> Result<Args> {
    let mut args = Args {
        config: None,
        machine: None,
        port_base: 29500,
        inproc: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--machine" => {
                let v = it.next().context("--machine needs a value")?;
                args.machine = Some(v.parse().context("--machine")?);
            }
            "--port-base" => {
                let v = it.next().context("--port-base needs a value")?;
                args.port_base = v.parse().context("--port-base")?;
            }
            "--inproc" => args.inproc = true,
            flag if flag.starts_with("--") => {
                bail!(
                    "unknown flag {flag}; usage: launch [config.cfg] \
                     [--machine M --port-base P | --inproc]"
                );
            }
            path => args.config = Some(path.to_string()),
        }
    }
    ensure!(
        args.machine.is_none() || !args.inproc,
        "--machine and --inproc are mutually exclusive"
    );
    Ok(args)
}

/// The surrogate's variant spec: shapes only (no HLO/artifacts), enough
/// for the loader to build the usual padded 2-layer batches.
fn surrogate_vspec(cfg: &RunConfig) -> VariantSpec {
    let batch = 16usize;
    VariantSpec {
        name: "launch-surrogate".into(),
        model: ModelKind::Sage,
        task: TaskKind::NodeClassification,
        batch,
        fanouts: vec![3, 3],
        layer_nodes: vec![
            (batch * 16).next_multiple_of(128),
            (batch * 4).next_multiple_of(128),
            batch.next_multiple_of(128),
        ],
        feat_dim: cfg.dataset.feat_dim,
        num_classes: cfg.dataset.num_classes,
        num_heads: 1,
        num_rels: 1,
        param_shapes: Vec::new(),
        train_inputs: Vec::new(),
        eval_inputs: Vec::new(),
        train_hlo: String::new(),
        eval_hlo: String::new(),
        params_bin: String::new(),
    }
}

/// One softmax-regression SGD step over the batch's labeled seed rows
/// (layer-0 rows `0..nL` are the seeds — `compact::to_block` places dst
/// nodes first). Pure f32 arithmetic in a fixed order, so the loss and
/// the updated params are bit-identical across backends and processes.
fn surrogate_step(
    params: &mut [Vec<f32>],
    batch: &HostBatch,
    fd: usize,
    nc: usize,
    lr: f32,
) -> f32 {
    let (w, b) = params.split_at_mut(1);
    let (w, b) = (&mut w[0], &mut b[0]);
    let mut gw = vec![0.0f32; fd * nc];
    let mut gb = vec![0.0f32; nc];
    let mut loss = 0.0f32;
    let mut cnt = 0.0f32;
    for (i, (&y, &mk)) in
        batch.labels.iter().zip(&batch.label_mask).enumerate()
    {
        if mk <= 0.0 || y < 0 || y as usize >= nc {
            continue;
        }
        let y = y as usize;
        let x = &batch.feats[i * fd..(i + 1) * fd];
        let mut logits: Vec<f32> = (0..nc)
            .map(|c| {
                let mut v = b[c];
                for (k, &xk) in x.iter().enumerate() {
                    v += xk * w[k * nc + c];
                }
                v
            })
            .collect();
        let mx =
            logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut z = 0.0f32;
        for l in logits.iter_mut() {
            *l = (*l - mx).exp();
            z += *l;
        }
        loss -= (logits[y] / z).ln();
        cnt += 1.0;
        for (c, &e) in logits.iter().enumerate() {
            let g = e / z - if c == y { 1.0 } else { 0.0 };
            gb[c] += g;
            for (k, &xk) in x.iter().enumerate() {
                gw[k * nc + c] += g * xk;
            }
        }
    }
    if cnt == 0.0 {
        return 0.0;
    }
    let s = lr / cnt;
    for (wv, g) in w.iter_mut().zip(&gw) {
        *wv -= s * g;
    }
    for (bv, g) in b.iter_mut().zip(&gb) {
        *bv -= s * g;
    }
    loss / cnt
}

fn fnv1a(h: &mut u64, x: u64) {
    for byte in x.to_le_bytes() {
        *h ^= byte as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

fn hash_params(params: &[Vec<f32>]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for p in params {
        for v in p {
            fnv1a(&mut h, v.to_bits() as u64);
        }
    }
    h
}

struct MachineResult {
    machine: usize,
    /// Per local rank: (rank, batch-stream hash).
    streams: Vec<(usize, u64)>,
    param_hash: u64,
    loss_start: f32,
    final_loss: f32,
}

impl MachineResult {
    /// The line `scripts/launch.sh` compares verbatim between backends.
    fn line(&self) -> String {
        let streams: Vec<String> = self
            .streams
            .iter()
            .map(|(r, h)| format!("{r}:{h:016x}"))
            .collect();
        format!(
            "MACHINE_RESULT m={} streams={} param_hash={:016x} \
             loss_start={:.6} final_loss={:.6}",
            self.machine,
            streams.join(","),
            self.param_hash,
            self.loss_start,
            self.final_loss,
        )
    }
}

/// Everything one machine process does after deploy: serve its KV
/// shard, join the rendezvous, cross-check a peer's shard over RPC,
/// train its local ranks with per-epoch wire barriers, say goodbye.
#[allow(clippy::too_many_arguments)]
fn run_machine(
    cluster: &Cluster,
    transport: &Arc<Transport>,
    group: &Arc<AllReduceGroup>,
    cfg: &RunConfig,
    vspec: &VariantSpec,
    layout: &Layout,
    m: usize,
) -> Result<MachineResult> {
    let per = cfg.cluster.trainers_per_machine;
    let n_mach = layout.n_mach;

    // data plane: serve this machine's KVStore shard over the wire
    let running = Arc::new(AtomicBool::new(true));
    let kv_thread = serve_kv(
        transport.endpoint(layout.kv_serve(m)),
        cluster.kv.servers[m].clone(),
        running.clone(),
    );

    // control plane: join the rendezvous (machine id = our preference)
    let mut rdv = RendezvousClient::join(
        transport.endpoint(layout.control(m)),
        layout.server(),
        Some(m as u32),
        Duration::from_secs(60),
    )?;
    ensure!(
        rdv.machine() as usize == m,
        "rendezvous assigned machine {} to process {m}",
        rdv.machine()
    );
    let ranks = rdv.my_ranks();
    ensure!(ranks == (m * per..(m + 1) * per).collect::<Vec<_>>());

    // start barrier: every process deployed + serving before anyone
    // pulls
    match rdv.barrier_all(&ranks).map_err(anyhow::Error::from)? {
        Decision::Continue => {}
        Decision::Reconfigure(v) => {
            bail!("membership changed before training started: {v:?}")
        }
    }

    // cross-process data-plane check: pull label rows from the next
    // machine's server over real RPC and compare against our replica
    let peer = (m + 1) % n_mach;
    if n_mach > 1 {
        let mut rpc =
            RpcClient::new(transport.endpoint(layout.kv_client(m)));
        let locals: Vec<u32> = (0..4).collect();
        let (dim, remote) = rpc
            .kv_pull(layout.kv_serve(peer), "label", &locals)
            .map_err(anyhow::Error::from)?;
        let mut local = vec![0.0f32; locals.len() * dim];
        cluster.kv.servers[peer]
            .read_rows("label", &locals, &mut local)
            .map_err(anyhow::Error::from)?;
        ensure!(
            remote == local,
            "RPC pull from machine {peer} disagrees with the replica"
        );
        println!("KV_CROSSCHECK m={m} peer={peer} rows={} ok", dim * 4);
    }

    // the unmodified loader path: one DistNodeDataLoader per local rank
    let graph = DistGraph::new(cluster);
    let fd = vspec.feat_dim;
    let nc = vspec.num_classes;
    let mut loaders: Vec<DistNodeDataLoader> = Vec::new();
    for &r in &ranks {
        loaders.push(
            DistNodeDataLoader::builder(&graph, vspec)
                .rank(r)
                .seeds(Seeds::Train)
                .seed(cfg.train.seed ^ ((r as u64) << 17))
                .build()?,
        );
    }
    let mut participants = Vec::new();
    for &r in &ranks {
        participants.push(group.endpoint(r).map_err(|e| {
            anyhow::anyhow!("claiming ring rank {r}: {e}")
        })?);
    }
    let mut params: Vec<Vec<Vec<f32>>> = ranks
        .iter()
        .map(|_| vec![vec![0.0f32; fd * nc], vec![0.0f32; nc]])
        .collect();
    let mut losses: Vec<Vec<f32>> =
        ranks.iter().map(|_| Vec::new()).collect();
    let mut hashes: Vec<u64> =
        ranks.iter().map(|_| 0xcbf2_9ce4_8422_2325u64).collect();

    for epoch in 0..cfg.train.epochs {
        let t_epoch = std::time::Instant::now();
        // local ranks train concurrently; the ring syncs every step
        // across ALL processes, so global steps stay aligned
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (((loader, p), prm), (curve, hash)) in loaders
                .iter_mut()
                .zip(participants.iter_mut())
                .zip(params.iter_mut())
                .zip(losses.iter_mut().zip(hashes.iter_mut()))
            {
                handles.push(s.spawn(move || -> Result<()> {
                    for batch in &mut *loader {
                        let (input_nodes, seeds, _blocks) =
                            batch.unpack();
                        for &n in input_nodes {
                            fnv1a(hash, n as u64);
                        }
                        for &n in seeds {
                            fnv1a(hash, n as u64);
                        }
                        let loss = surrogate_step(
                            prm,
                            &batch,
                            fd,
                            nc,
                            cfg.train.lr,
                        );
                        p.allreduce_params(prm).map_err(|e| {
                            anyhow::anyhow!("all-reduce: {e}")
                        })?;
                        curve.push(loss);
                    }
                    Ok(())
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("trainer thread panicked"))
                .collect::<Result<Vec<()>>>()
        })?;
        // epoch boundary over the wire: heartbeats + barrier
        let secs = t_epoch.elapsed().as_secs_f64();
        for &r in &ranks {
            rdv.heartbeat(r, secs).map_err(anyhow::Error::from)?;
        }
        match rdv.barrier_all(&ranks).map_err(anyhow::Error::from)? {
            Decision::Continue => {}
            Decision::Reconfigure(v) => {
                // a peer process died mid-run; the fixed-membership
                // launcher reports and stops (the in-process elastic
                // driver handles live reconfiguration)
                bail!(
                    "membership shrank to {:?} at epoch {epoch} — a \
                     peer process is gone",
                    v.machines
                )
            }
        }
    }

    rdv.shutdown().map_err(anyhow::Error::from)?;
    running.store(false, Ordering::SeqCst);
    kv_thread.join().expect("kv serve thread panicked");

    // after the final all-reduce every rank's params are identical;
    // hash the first local rank's copy
    let curve = &losses[0];
    ensure!(!curve.is_empty(), "loader yielded no training batches");
    let k = curve.len().clamp(1, 5);
    Ok(MachineResult {
        machine: m,
        streams: ranks.iter().copied().zip(hashes).collect(),
        param_hash: hash_params(&params[0]),
        loss_start: curve[..k].iter().sum::<f32>() / k as f32,
        final_loss: curve[curve.len() - k..].iter().sum::<f32>()
            / k as f32,
    })
}

fn main() -> Result<()> {
    let args = parse_args()?;
    let cfg = match &args.config {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig {
            dataset: distdglv2::graph::DatasetSpec::new(
                "launch-default",
                4000,
                16_000,
            ),
            ..RunConfig::default()
        },
    };
    let n_mach = cfg.cluster.n_machines;
    let per = cfg.cluster.trainers_per_machine;
    let world = n_mach * per;
    let layout = Layout { world, n_mach };
    if let Some(m) = args.machine {
        ensure!(m < n_mach, "--machine {m} >= machines {n_mach}");
    }

    println!(
        "launch: {n_mach} machines x {per} trainers, {} epochs, \
         backend={}",
        cfg.train.epochs,
        if args.inproc { "in-process" } else { "tcp" },
    );

    // deterministic replicated deployment: every process builds the
    // same dataset and cluster from the config's seeds
    let dataset = cfg.dataset.generate();
    let cluster = Arc::new(Cluster::deploy(
        &dataset,
        cfg.cluster.clone(),
        artifacts_dir(),
    )?);
    let vspec = surrogate_vspec(&cfg);

    let cost = Arc::new(CostModel::default());
    let endpoint_machine: Vec<u32> = (0..layout.n_endpoints())
        .map(|e| layout.proc_of(e, per) as u32)
        .collect();
    // rendezvous liveness: reaping is for crashed processes, not slow
    // epochs — keep the timeout far above any smoke epoch
    let co_cfg = CoordinatorConfig {
        heartbeat_timeout: Duration::from_secs(120),
        ..Default::default()
    };

    let mut results: Vec<MachineResult> = Vec::new();
    if args.inproc {
        // whole cluster in this process over the in-process backend —
        // the reference run the TCP launch must match byte for byte
        let transport =
            Transport::with_mapping(endpoint_machine, cost);
        let group =
            AllReduceGroup::from_transport(transport.clone(), world);
        let server = RendezvousServer::new(
            transport.endpoint(layout.server()),
            MembershipView::initial(n_mach, per),
            co_cfg,
            n_mach,
        );
        let server_thread = std::thread::spawn(move || server.run());
        let outs = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for m in 0..n_mach {
                let (cluster, transport, group) =
                    (&cluster, &transport, &group);
                let (cfg, vspec, layout) = (&cfg, &vspec, &layout);
                handles.push(s.spawn(move || {
                    run_machine(
                        cluster, transport, group, cfg, vspec, layout,
                        m,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("machine thread panicked"))
                .collect::<Result<Vec<_>>>()
        })?;
        results.extend(outs);
        let boundaries = server_thread
            .join()
            .expect("rendezvous server panicked");
        println!("rendezvous: {boundaries} epoch boundaries decided");
    } else {
        let m = args.machine.context(
            "pass --machine M (one process per machine) or --inproc",
        )?;
        let mut tcfg = TcpConfig::localhost(m, n_mach, args.port_base);
        tcfg.endpoint_proc = (0..layout.n_endpoints())
            .map(|e| layout.proc_of(e, per))
            .collect();
        tcfg.machine_of = endpoint_machine;
        let transport =
            tcp_transport(tcfg, cost).map_err(anyhow::Error::from)?;
        let group =
            AllReduceGroup::from_transport(transport.clone(), world);
        // machine 0 hosts the rendezvous service
        let server_thread = (m == 0).then(|| {
            let server = RendezvousServer::new(
                transport.endpoint(layout.server()),
                MembershipView::initial(n_mach, per),
                co_cfg,
                n_mach,
            );
            std::thread::spawn(move || server.run())
        });
        results.push(run_machine(
            &cluster, &transport, &group, &cfg, &vspec, &layout, m,
        )?);
        if let Some(h) = server_thread {
            let boundaries =
                h.join().expect("rendezvous server panicked");
            println!(
                "rendezvous: {boundaries} epoch boundaries decided"
            );
        }
    }

    results.sort_by_key(|r| r.machine);
    for r in &results {
        println!("{}", r.line());
    }
    let r0 = &results[0];
    ensure!(
        r0.final_loss < r0.loss_start,
        "loss did not decrease: {} -> {}",
        r0.loss_start,
        r0.final_loss
    );
    println!("LAUNCH OK");
    Ok(())
}
