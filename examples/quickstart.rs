//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Generates a synthetic power-law graph, deploys a 2-machine simulated
//! cluster (partition → KVStore → sampler servers), wraps it in the
//! DGL-style `api::DistGraph` handle, trains GraphSAGE for one epoch with
//! the asynchronous pipeline, and prints the loss curve. For a
//! hand-written loop over the same API see `examples/custom_loop.rs`.
//!
//! Run:  make artifacts && cargo run --release --example quickstart

use distdglv2::api::DistGraph;
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    // 1. A dataset: 20K-node RMAT graph with label-correlated features.
    let dataset = DatasetSpec::new("quickstart", 20_000, 120_000).generate();

    // 2. Deploy a simulated cluster: 2 machines x 2 trainers.
    //    METIS partitioning, halo construction, KVStore, samplers.
    let cluster = Cluster::deploy(
        &dataset,
        ClusterSpec::new(2, 2),
        artifacts_dir(),
    )?;

    // 3. The DGL-style handle: counts, schema, splits, feature pulls.
    let graph = DistGraph::new(&cluster);
    println!(
        "graph: {} nodes, {} edges, {} classes, feat dim {}",
        graph.num_nodes_total(),
        graph.num_edges_total(),
        graph.num_classes(),
        graph.ndata_dim(),
    );
    println!(
        "deployed: edge cut {} ({:.1}% of edges), locality-aware split: {} \
         train items per trainer",
        cluster.stats.edge_cut,
        100.0 * cluster.edge_cut_frac(),
        graph.train_idx(0).len()
    );

    // 4. Train GraphSAGE (AOT-compiled HLO; Python is not involved).
    //    trainer::train drains one api::DistNodeDataLoader per rank.
    let cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        lr: 0.3,
        epochs: 1,
        eval_each_epoch: true,
        ..Default::default()
    };
    let report = trainer::train(&cluster, &cfg)?;

    println!("\nloss curve:");
    for (i, l) in report.loss_curve.iter().enumerate() {
        println!("  step {i:>3}  loss {l:.4}");
    }
    println!(
        "\n{} steps in {:.2}s ({:.1} steps/s) | network {} KiB | PCIe {} KiB",
        report.steps,
        report.total_secs,
        report.steps as f64 / report.total_secs,
        report.net_bytes / 1024,
        report.pcie_bytes / 1024,
    );
    if let Some(acc) = report.final_val_acc {
        println!(
            "validation accuracy: {acc:.3} (chance = {:.3})",
            1.0 / graph.num_classes() as f64
        );
    }
    Ok(())
}
