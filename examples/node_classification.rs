//! Node classification across all three GNN models (§6's first task).
//!
//! Trains GraphSAGE, GAT, and RGCN on an ogbn-products-shaped synthetic
//! workload (power-law + community structure, 8.2% labeled), comparing
//! convergence, throughput, and the communication profile per model.
//!
//! Run:  make artifacts && cargo run --release --example node_classification

use distdglv2::api::DistGraph;
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    // ogbn-products *structure* at reduced scale, dims matched to the dev
    // artifact shapes (feat 32 / 16 classes)
    let mut dspec = DatasetSpec::new("products-s", 60_000, 400_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.082; // products' labeled fraction
    let dataset = dspec.generate();
    println!(
        "dataset {}: {} nodes, {} edges, {} train nodes",
        dataset.name,
        dataset.n_nodes(),
        dataset.graph.n_edges(),
        dataset
            .nodes_with(distdglv2::graph::SplitTag::Train)
            .len(),
    );

    for (variant, lr) in
        [("sage_nc_dev", 0.3f32), ("gat_nc_dev", 0.5), ("rgcn_nc_dev", 0.3)]
    {
        let cluster =
            Cluster::deploy(&dataset, ClusterSpec::new(2, 2), artifacts_dir())?;
        let graph = DistGraph::new(&cluster);
        println!(
            "\ndeployed for {variant}: edge cut {:.1}%, {} train items x {} \
             trainers",
            100.0 * cluster.edge_cut_frac(),
            graph.train_idx(0).len(),
            graph.n_trainers(),
        );
        let cfg = TrainConfig {
            variant: variant.into(),
            lr,
            epochs: 2,
            eval_each_epoch: true,
            ..Default::default()
        };
        let report = trainer::train(&cluster, &cfg)?;
        println!("\n== {variant} ==");
        for e in &report.epochs {
            println!("  epoch {} loss {:.4} ({:.2}s)", e.epoch, e.mean_loss, e.secs);
        }
        println!(
            "  {:.1} steps/s | val acc {:.3} | remote rows {} | net {} KiB \
             | modeled net {:.1} ms | pcie {} KiB",
            report.steps as f64 / report.total_secs,
            report.final_val_acc.unwrap_or(f64::NAN),
            report.remote_feature_rows,
            report.net_bytes / 1024,
            cluster.cost.modeled_network_secs() * 1e3,
            report.pcie_bytes / 1024,
        );
    }
    Ok(())
}
