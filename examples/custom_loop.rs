//! The "no code modification" claim, demonstrated: a hand-written
//! training loop over the public `api` surface — the same
//! `DistGraph` + `DistNodeDataLoader` pair `trainer::train` itself
//! drains — with an explicit device step, an explicit ring all-reduce,
//! and an offline inference pass over every test node. Nothing here
//! touches the pipeline, sampler, or KVStore internals; under the same
//! seed the loaders stream batches byte-identical to the built-in
//! trainer's (test-enforced in `api::loader` and
//! `tests/integration.rs`).
//!
//! Run:  make artifacts && cargo run --release --example custom_loop

use distdglv2::api::{DistGraph, DistNodeDataLoader, NeighborSampler, Seeds};
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{AllReduceGroup, DeviceExecutor};

fn main() -> anyhow::Result<()> {
    // deployment is unchanged: generate, partition, load the KVStore
    let dataset =
        DatasetSpec::new("custom-loop", 20_000, 120_000).generate();
    let cluster = Cluster::deploy(
        &dataset,
        ClusterSpec::new(2, 1),
        artifacts_dir(),
    )?;
    let graph = DistGraph::new(&cluster);
    println!(
        "graph: {} nodes, {} edges | {} trainers | {} train items/rank",
        graph.num_nodes_total(),
        graph.num_edges_total(),
        graph.n_trainers(),
        graph.train_idx(0).len(),
    );

    // this loop owns the device executors and the all-reduce plane —
    // the pieces trainer::train normally wires up
    let variant = "sage_nc_dev";
    let mut devices = Vec::new();
    for _ in 0..cluster.spec.n_machines {
        devices.push(DeviceExecutor::spawn(
            cluster.artifacts.clone(),
            variant.into(),
            Some(cluster.cost.clone()),
        )?);
    }
    let spec = devices[0].spec()?;
    let init_params = devices[0].initial_params()?;
    let machine_of: Vec<u32> = (0..graph.n_trainers())
        .map(|t| cluster.machine_of_trainer(t))
        .collect();
    let ar = AllReduceGroup::new(machine_of.clone(), cluster.cost.clone());

    // one loader per rank: the DGL NodeDataLoader shape — seeds, a
    // NeighborSampler value object, batching/shuffling knobs
    let sampler = NeighborSampler::from_variant(&spec);
    let mut loaders = Vec::new();
    for rank in 0..graph.n_trainers() {
        loaders.push(
            DistNodeDataLoader::builder(&graph, &spec)
                .rank(rank)
                .seeds(Seeds::Train)
                .sampler(sampler.clone())
                .seed(7 ^ (rank as u64) << 17)
                .build()?,
        );
    }
    let epochs = 2usize;
    let lr = 0.3f32;
    println!(
        "training {epochs} epochs x {} batches/epoch, hand-written loop",
        loaders[0].len()
    );

    // == the custom training loop ==========================================
    let n_layers = spec.fanouts.len();
    let mut handles = Vec::new();
    for (rank, mut loader) in loaders.into_iter().enumerate() {
        let device = devices[machine_of[rank] as usize].handle();
        let ep = ar.endpoint(rank);
        let mut params = init_params.clone();
        handles.push(std::thread::spawn(
            move || -> anyhow::Result<(Vec<f32>, Vec<Vec<f32>>)> {
                let mut losses = Vec::new();
                let pool = loader.pool();
                let mut input_rows = 0usize;
                let mut seed_rows = 0usize;
                for _epoch in 0..epochs {
                    // the DGL idiom: one `for` per epoch, each batch is
                    // the (input_nodes, seeds, blocks) triple plus the
                    // pre-pulled features/labels
                    for batch in &mut loader {
                        let (input_nodes, seeds, blocks) = batch.unpack();
                        assert_eq!(blocks.len(), n_layers);
                        input_rows += input_nodes.len();
                        seed_rows += seeds.len();
                        // explicit device step...
                        let (loss, spent) =
                            device.train_reusing(&mut params, batch, lr)?;
                        pool.put(spent); // recycle the buffers (§Perf)
                        losses.push(loss);
                        // ...and explicit synchronous-SGD barrier
                        ep.allreduce_params(&mut params);
                    }
                }
                println!(
                    "rank {rank}: frontier expansion {:.1}x \
                     ({input_rows} input rows / {seed_rows} seeds)",
                    input_rows as f64 / seed_rows.max(1) as f64
                );
                Ok((losses, params))
            },
        ));
    }
    let mut curves = Vec::new();
    let mut params = init_params;
    for h in handles {
        let (losses, p) = h.join().expect("trainer thread panicked")?;
        curves.push(losses);
        params = p;
    }
    let losses = &curves[0];
    println!("loss curve (every 4th step):");
    for (i, l) in losses.iter().enumerate().step_by(4) {
        println!("  step {i:>3}  loss {l:.4}");
    }

    // == offline inference over every test node ============================
    // the same loader machinery, pointed at an arbitrary seed list with
    // shuffling off — something the monolithic trainer never offered
    let test_nodes = graph.test_idx().to_vec();
    let mut infer = DistNodeDataLoader::builder(&graph, &spec)
        .seeds(Seeds::Nodes(test_nodes.clone()))
        .shuffle(false)
        .build()?;
    let device = devices[0].handle();
    let classes = graph.num_classes();
    let mut correct = 0usize;
    let mut total = 0usize;
    let pool = infer.pool();
    for batch in &mut infer {
        let seeds = batch.seeds().to_vec();
        let labels = graph.node_labels(&seeds);
        let logits = device.eval(&params, batch.clone())?;
        pool.put(batch);
        for (i, &y) in labels.iter().enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j as u16)
                .unwrap();
            correct += usize::from(argmax == y);
            total += 1;
        }
    }
    let acc = correct as f64 / total.max(1) as f64;
    println!(
        "\ninference: {total} test nodes in {} batches | accuracy {acc:.3} \
         (chance {:.3})",
        infer.len(),
        1.0 / classes as f64
    );

    let k = losses.len().min(4).max(1);
    let first = losses[..k].iter().sum::<f32>() / k as f32;
    let last = losses[losses.len() - k..].iter().sum::<f32>() / k as f32;
    anyhow::ensure!(total == test_nodes.len(), "inference missed nodes");
    anyhow::ensure!(last < first, "loss did not decrease: {first} -> {last}");
    anyhow::ensure!(
        acc > 1.5 / classes as f64,
        "accuracy did not beat chance: {acc}"
    );
    println!("\nCUSTOM LOOP PASSED (loss {first:.3} -> {last:.3})");
    Ok(())
}
