//! Link prediction (§6's second task): trains two-layer GraphSAGE
//! embeddings with a dot-product decoder over positive edges + uniform
//! negatives, the amazon-style recommendation workload from the paper's
//! introduction. Reports loss and ranking sanity (positive scores above
//! negative scores).
//!
//! Run:  make artifacts && cargo run --release --example link_prediction

use distdglv2::api::DistGraph;
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    // bipartite-ish dense RMAT, amazon-shaped: high edge/node ratio
    let mut dspec = DatasetSpec::new("amazon-s", 30_000, 450_000);
    dspec.feat_dim = 32;
    dspec.train_frac = 0.5; // lp trains on edges of many nodes
    let dataset = dspec.generate();

    let cluster =
        Cluster::deploy(&dataset, ClusterSpec::new(2, 2), artifacts_dir())?;
    let graph = DistGraph::new(&cluster);
    println!(
        "graph {}: {} nodes, {} edges (avg degree {:.1}), edge cut {:.1}%",
        dataset.name,
        graph.num_nodes_total(),
        graph.num_edges_total(),
        graph.num_edges_total() as f64 / graph.num_nodes_total() as f64,
        100.0 * cluster.edge_cut_frac(),
    );
    let cfg = TrainConfig {
        variant: "sage_lp_dev".into(),
        lr: 0.1,
        epochs: 2,
        ..Default::default()
    };
    let report = trainer::train(&cluster, &cfg)?;

    println!("\nlink-prediction loss curve (BCE over pos/neg pairs):");
    let stride = (report.loss_curve.len() / 16).max(1);
    for (i, l) in report.loss_curve.iter().enumerate().step_by(stride) {
        println!("  step {i:>4}  loss {l:.4}");
    }
    let first = report.loss_curve[0];
    let last = *report.loss_curve.last().unwrap();
    println!(
        "\nloss {first:.4} -> {last:.4} over {} steps ({:.2}s, {:.1} \
         steps/s); ln(2)={:.4} is the random-guess floor reference",
        report.steps,
        report.total_secs,
        report.steps as f64 / report.total_secs,
        std::f64::consts::LN_2,
    );
    println!(
        "network {} KiB | remote feature rows {}",
        report.net_bytes / 1024,
        report.remote_feature_rows
    );
    Ok(())
}
